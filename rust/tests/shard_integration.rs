//! Integration: the sharded parameter server end to end — the `--shards 1`
//! byte-identity contract against a reference replay of the historical
//! single-leader algorithm, bit-determinism over (shards × threads),
//! sharded checkpoint save/restore, and exact per-shard wire-bit
//! accounting at both the codec and fabric levels.

use ef_sgd::collectives::ShardPlan;
use ef_sgd::compress::wire::{self, SHARD_TAG_BITS};
use ef_sgd::compress::{Compressor, Qsgd};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::async_driver::AsyncTrainDriver;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::state::CheckpointStore;
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::{Aggregation, LrSchedule};
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::message::FRAME_OVERHEAD_BITS;
use ef_sgd::net::MessageKind;
use ef_sgd::util::Pcg64;

fn quadratic_workers(n: usize, d: usize, kind: CompressorKind) -> Vec<Worker> {
    (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::new(40, 100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                kind,
                4,
                4,
                Pcg64::new(41, id as u64),
            )
        })
        .collect()
}

/// Replay the pre-sharding single-leader algorithm directly: every worker
/// steps + encodes its full-vector frame, the frames decode densely in
/// worker order, the mean applies to theta. For n ≤ DECODE_LANES the
/// driver's fixed-group fused reduction replays exactly this order, so
/// this is a bit-faithful reference for the unsharded trajectory.
fn reference_run(
    mut workers: Vec<Worker>,
    mut theta: Vec<f32>,
    steps: usize,
    lr: f32,
) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    for _ in 0..steps {
        let frames: Vec<wire::Encoded> = workers
            .iter_mut()
            .map(|w| w.step_encode(&theta, lr))
            .collect();
        let updates: Vec<Vec<f32>> = frames
            .iter()
            .map(|e| wire::decode_any(e).unwrap())
            .collect();
        let agg = Aggregation::Mean.combine(&updates);
        ef_sgd::tensor::sub_assign(&mut theta, &agg);
    }
    let errors = workers.iter().map(|w| w.export_error()).collect();
    let corrected = workers.iter().map(|w| w.export_corrected()).collect();
    (theta, errors, corrected)
}

/// `--shards 1` produces a byte-identical Snapshot to the pre-sharding
/// driver: theta, every EF residual, and every corrected gradient match
/// the reference replay exactly, for fixed-length (scaled-sign) and
/// variable-length (QSGD) frames alike.
#[test]
fn shards_one_matches_unsharded() {
    for kind in [CompressorKind::ScaledSign, CompressorKind::Qsgd] {
        let d = 96;
        let n = 4;
        let steps = 12;
        let lr = 0.05f32;
        let cfg = DriverConfig {
            steps,
            schedule: LrSchedule::constant(lr as f64),
            shards: 1,
            ..Default::default()
        };
        let mut driver = TrainDriver::new(cfg, quadratic_workers(n, d, kind), vec![1.0f32; d]);
        let mut rec = Recorder::new();
        for _ in 0..steps {
            driver.round(&mut rec);
        }
        let snap = driver.snapshot();
        assert_eq!(snap.shards, 1);
        let (theta_ref, errs_ref, corr_ref) =
            reference_run(quadratic_workers(n, d, kind), vec![1.0f32; d], steps, lr);
        assert_eq!(snap.theta, theta_ref, "{kind:?}: theta diverged");
        assert_eq!(snap.worker_errors, errs_ref, "{kind:?}: residuals diverged");
        assert_eq!(
            snap.worker_corrected, corr_ref,
            "{kind:?}: corrected grads diverged"
        );
    }
}

fn sharded_run(
    kind: CompressorKind,
    shards: usize,
    threads: usize,
) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, u64, u64) {
    let d = 97; // ragged split on purpose
    let n = 5;
    let steps = 12;
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.05),
        shards,
        threads,
        ..Default::default()
    };
    let mut driver = TrainDriver::new(cfg, quadratic_workers(n, d, kind), vec![1.0f32; d]);
    let mut rec = Recorder::new();
    for _ in 0..steps {
        driver.round(&mut rec);
    }
    let snap = driver.snapshot();
    let t = driver.traffic();
    (
        snap.theta,
        snap.worker_errors,
        snap.worker_corrected,
        t.total_bits,
        t.bits_of_kind(MessageKind::GradPush),
    )
}

/// Any (shards, threads) combination is bit-deterministic: the trained
/// parameters, every EF tensor, and the exact wire-bit totals are
/// identical at 1 and 4 threads for S ∈ {1, 2, 4}, for both fixed- and
/// variable-length wire formats.
#[test]
fn sharded_is_bit_deterministic() {
    for kind in [CompressorKind::ScaledSign, CompressorKind::Qsgd] {
        for shards in [1usize, 2, 4] {
            let (theta1, errs1, corr1, bits1, push1) = sharded_run(kind, shards, 1);
            let (theta4, errs4, corr4, bits4, push4) = sharded_run(kind, shards, 4);
            assert_eq!(theta1, theta4, "{kind:?} S={shards}: theta differs");
            assert_eq!(errs1, errs4, "{kind:?} S={shards}: residuals differ");
            assert_eq!(corr1, corr4, "{kind:?} S={shards}: corrected differ");
            assert_eq!(bits1, bits4, "{kind:?} S={shards}: total bits differ");
            assert_eq!(push1, push4, "{kind:?} S={shards}: push bits differ");
        }
    }
}

/// Sharded checkpointing: a 4-shard run snapshotted at round 10, saved
/// through the on-disk store, restored into a fresh 4-shard driver, and
/// resumed for 10 more rounds lands exactly where the uninterrupted run
/// does (blockwise EF state round-trips through the full-length tensors).
#[test]
fn sharded_checkpoint_restore_resumes_identically() {
    let d = 64;
    let shards = 4;
    let n = 3;
    let mk = || quadratic_workers(n, d, CompressorKind::ScaledSign);
    let cfg = |steps: usize| DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.1),
        shards,
        ..Default::default()
    };

    // run A: 20 straight rounds
    let mut a = TrainDriver::new(cfg(20), mk(), vec![1.0f32; d]);
    let mut rec = Recorder::new();
    for _ in 0..20 {
        a.round(&mut rec);
    }

    // run B: 10 rounds, snapshot through the on-disk store
    let mut b = TrainDriver::new(cfg(10), mk(), vec![1.0f32; d]);
    let mut recb = Recorder::new();
    for _ in 0..10 {
        b.round(&mut recb);
    }
    let snap = b.snapshot();
    assert_eq!(snap.round, 10);
    assert_eq!(snap.shards, shards);
    let dir = std::env::temp_dir().join(format!("efsgd_shard_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = CheckpointStore::new(&dir).unwrap();
    store.save(&snap).unwrap();
    let loaded = store.load().unwrap();
    assert_eq!(loaded.shards, shards);
    assert_eq!(loaded.theta, snap.theta);
    assert_eq!(loaded.worker_errors, snap.worker_errors);
    assert_eq!(loaded.worker_corrected, snap.worker_corrected);

    // run C: fresh sharded driver, restore, 10 more rounds
    let mut c = TrainDriver::new(cfg(0), mk(), vec![1.0f32; d]);
    c.restore(&loaded);
    let mut recc = Recorder::new();
    for _ in 0..10 {
        c.round(&mut recc);
    }
    let sa = a.snapshot();
    let sc = c.snapshot();
    assert_eq!(sa.round, sc.round);
    assert_eq!(sa.theta, sc.theta, "restored run diverged");
    assert_eq!(sa.worker_errors, sc.worker_errors);
    assert_eq!(sa.worker_corrected, sc.worker_corrected);
    std::fs::remove_dir_all(&dir).ok();
}

/// Exact per-shard wire-bit accounting at the codec level: dense shard
/// frames partition the unsharded payload exactly (plus one 48-bit shard
/// tag each), and QSGD shard frames of one quantized vector cost the
/// unsharded Elias stream plus one extra 40-bit qsgd header per extra
/// shard plus the tags — i.e. ≤ unsharded + S·(header + tag).
#[test]
fn per_shard_wire_bits_account_exactly() {
    const QSGD_HEADER_BITS: u64 = 32 + 8;
    let d = 1000;
    let s_count = 4;
    let plan = ShardPlan::new(d, s_count);
    let mut rng = Pcg64::seeded(3);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 0.0, 1.0);

    // dense: sum over shards == unsharded total (+ the tags)
    let unsharded = wire::encode_dense(&v);
    let total: u64 = (0..s_count)
        .map(|s| {
            let r = plan.range(s);
            wire::encode_dense(&v[r.clone()])
                .with_shard(s as u16, r.start as u32)
                .bits
        })
        .sum();
    assert_eq!(total, unsharded.bits + s_count as u64 * SHARD_TAG_BITS);
    assert_eq!(total - s_count as u64 * SHARD_TAG_BITS, unsharded.bits);

    // qsgd: slicing one quantized vector (same norm, same level count)
    // reproduces the per-coordinate Elias codes exactly, so the sharded
    // total is unsharded + (S-1) extra headers + S tags — within the
    // S·(header + tag) bound
    let levels = 4u32;
    let q = Qsgd::new(levels).compress_vec(&v, &mut Pcg64::seeded(7));
    let norm = ef_sgd::tensor::norm2(&v) as f32;
    let un_q = wire::encode_qsgd(&q, norm, levels);
    let total_q: u64 = (0..s_count)
        .map(|s| {
            let r = plan.range(s);
            wire::encode_qsgd(&q[r.clone()], norm, levels)
                .with_shard(s as u16, r.start as u32)
                .bits
        })
        .sum();
    assert_eq!(
        total_q,
        un_q.bits + (s_count as u64 - 1) * QSGD_HEADER_BITS + s_count as u64 * SHARD_TAG_BITS
    );
    assert!(total_q <= un_q.bits + s_count as u64 * (QSGD_HEADER_BITS + SHARD_TAG_BITS));
}

/// Exact per-shard accounting at the fabric level: in a sharded run every
/// push and broadcast message is shard-attributed, the per-shard bit map
/// partitions the push+broadcast totals exactly, and the scaled-sign push
/// total matches the analytic formula to the bit.
#[test]
fn sharded_fabric_traffic_partitions_exactly() {
    let d = 64u64;
    let shards = 4u64;
    let n = 3u64;
    let steps = 4u64;
    let cfg = DriverConfig {
        steps: steps as usize,
        schedule: LrSchedule::constant(0.05),
        shards: shards as usize,
        ..Default::default()
    };
    let out = TrainDriver::new(
        cfg,
        quadratic_workers(n as usize, d as usize, CompressorKind::ScaledSign),
        vec![1.0f32; d as usize],
    )
    .run();
    let push = out.traffic.bits_of_kind(MessageKind::GradPush);
    // per worker per round: sum over shards of (d_s + 32) sign payload +
    // 48-bit shard tag + 64-byte frame overhead per message
    let expect = steps * n * (d + shards * (32 + SHARD_TAG_BITS + FRAME_OVERHEAD_BITS));
    assert_eq!(push, expect);
    // every shard saw traffic, and the shard map partitions push+broadcast
    let bcast = out.traffic.bits_of_kind(MessageKind::ParamBroadcast);
    let mut per_shard_sum = 0u64;
    for s in 0..shards as u32 {
        let bits = out.traffic.bits_of_shard(s);
        assert!(bits > 0, "shard {s} unaccounted");
        per_shard_sum += bits;
    }
    assert_eq!(per_shard_sum, push + bcast);
}

/// The degenerate async setting (`quorum = n`, `max-staleness = 0`) stays
/// byte-identical to the synchronous driver under sharding too.
#[test]
fn async_sharded_degenerate_matches_sync_sharded() {
    let d = 48;
    let n = 4;
    let steps = 15;
    let cfg = || DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.05),
        shards: 2,
        ..Default::default()
    };
    let mut sync = TrainDriver::new(
        cfg(),
        quadratic_workers(n, d, CompressorKind::ScaledSign),
        vec![1.0f32; d],
    );
    let mut rec = Recorder::new();
    for _ in 0..steps {
        sync.round(&mut rec);
    }
    let mut asynch = AsyncTrainDriver::new(
        cfg(),
        n,
        0,
        quadratic_workers(n, d, CompressorKind::ScaledSign),
        vec![1.0f32; d],
    );
    let mut rec2 = Recorder::new();
    for _ in 0..steps {
        asynch.step_round(&mut rec2);
    }
    let a = sync.snapshot();
    let b = asynch.snapshot();
    assert_eq!(a.shards, b.shards);
    assert_eq!(a.theta, b.theta);
    assert_eq!(a.worker_errors, b.worker_errors);
    assert_eq!(a.worker_corrected, b.worker_corrected);
    assert_eq!(sync.traffic().total_bits, asynch.traffic().total_bits);
}
