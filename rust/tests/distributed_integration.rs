//! Integration: the full coordinator stack (workers + fabric + collectives
//! + EF state) on the native MLP workload — convergence, exact
//! communication accounting, and failure/restart behaviour.

use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver, UpdateRule};
use ef_sgd::coordinator::state::{CheckpointStore, Snapshot};
use ef_sgd::coordinator::worker::{GradSource, ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::data::synth_class::{self, Dataset, SynthSpec};
use ef_sgd::data::Sharder;
use ef_sgd::model::mlp::{Mlp, MlpConfig, MlpObjective};
use ef_sgd::net::message::FRAME_OVERHEAD_BITS;
use ef_sgd::net::MessageKind;
use ef_sgd::util::Pcg64;

struct ShardSource {
    inner: ObjectiveSource<MlpObjective>,
    test: Dataset,
}

impl GradSource for ShardSource {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64 {
        self.inner.grad(theta, out)
    }

    fn eval_acc(&mut self, theta: &[f32]) -> f64 {
        self.inner.obj.mlp.accuracy(theta, &self.test)
    }
}

fn setup(
    n_workers: usize,
    mode: WorkerMode,
    kind: CompressorKind,
) -> (Vec<Worker>, Vec<f32>, Mlp, Dataset) {
    let spec = SynthSpec::tiny();
    let mut rng = Pcg64::seeded(0);
    let (train, test) = synth_class::generate(&spec, &mut rng);
    let mlp = Mlp::new(MlpConfig {
        in_dim: spec.dim,
        hidden: vec![32],
        classes: spec.classes,
    });
    let theta0 = mlp.init_params(&mut Pcg64::seeded(1));
    let sharder = Sharder::new(&train, n_workers, &mut rng);
    let workers = sharder
        .shards
        .iter()
        .enumerate()
        .map(|(id, shard)| {
            Worker::new(
                id,
                Box::new(ShardSource {
                    inner: ObjectiveSource::new(
                        MlpObjective::new(mlp.clone(), shard.clone(), 8),
                        Pcg64::new(2, id as u64),
                    ),
                    test: test.clone(),
                }),
                mode,
                kind,
                8,
                4,
                Pcg64::new(3, id as u64),
            )
        })
        .collect();
    (workers, theta0, mlp, test)
}

#[test]
fn ef_signsgd_multiworker_learns_classification() {
    let (workers, theta0, mlp, test) =
        setup(4, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
    let steps = 600;
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::new(0.05, steps, vec![0.5, 0.75]),
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers, theta0).run();
    let acc = mlp.accuracy(&out.theta, &test);
    assert!(acc > 0.75, "test acc {acc}");
    // training loss decreased substantially
    let losses = &out.recorder.get("train_loss").unwrap().values;
    assert!(losses.last().unwrap() < &(losses.first().unwrap() * 0.5));
}

#[test]
fn push_traffic_matches_analytic_formula_exactly() {
    let n_workers = 3;
    let (workers, theta0, ..) =
        setup(n_workers, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
    let d = theta0.len() as u64;
    let steps = 7u64;
    let cfg = DriverConfig {
        steps: steps as usize,
        schedule: LrSchedule::constant(0.05),
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers, theta0).run();
    let push = out.traffic.bits_of_kind(MessageKind::GradPush);
    // exact: per push = (d + 32) payload + frame; pushes = workers * steps
    let expect = (d + 32 + FRAME_OVERHEAD_BITS) * n_workers as u64 * steps;
    assert_eq!(push, expect);
    // broadcast: dense params both ways accounting
    let bcast = out.traffic.bits_of_kind(MessageKind::ParamBroadcast);
    let expect_b = (32 * d + FRAME_OVERHEAD_BITS) * n_workers as u64 * steps;
    assert_eq!(bcast, expect_b);
}

#[test]
fn majority_vote_multiworker_descends() {
    let (workers, theta0, mlp, test) = setup(5, WorkerMode::SignVote, CompressorKind::Sign);
    let steps = 600;
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::new(0.01, steps, vec![0.5, 0.75]),
        aggregation: ef_sgd::coordinator::Aggregation::MajorityVote,
        update_rule: UpdateRule::ScaleByLr,
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers, theta0).run();
    let acc = mlp.accuracy(&out.theta, &test);
    assert!(acc > 0.4, "majority-vote acc {acc} (chance = 0.25)");
}

#[test]
fn checkpoint_to_disk_and_restore() {
    let dir = std::env::temp_dir().join(format!("efsgd_int_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (workers, theta0, ..) = setup(2, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
    let cfg = DriverConfig {
        steps: 10,
        schedule: LrSchedule::constant(0.05),
        checkpoint_every: 5,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers, theta0).run();
    let store = CheckpointStore::new(&dir).unwrap();
    assert!(store.exists());
    let snap: Snapshot = store.load().unwrap();
    assert_eq!(snap.round, 10);
    assert_eq!(snap.theta.len(), out.theta.len());
    assert_eq!(snap.worker_errors.len(), 2);
    // restoring into a fresh driver places theta and residuals back
    let (workers2, theta0b, ..) = setup(2, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
    let cfg2 = DriverConfig {
        steps: 0,
        schedule: LrSchedule::constant(0.05),
        ..Default::default()
    };
    let mut driver = TrainDriver::new(cfg2, workers2, theta0b);
    driver.restore(&snap);
    assert_eq!(driver.theta(), snap.theta.as_slice());
    for (state, e) in driver.worker_states().iter().zip(&snap.worker_errors) {
        assert_eq!(state.error, e.as_slice());
    }
    // the corrected gradient p is restored too (checkpoint bug fix)
    for (state, p) in driver.worker_states().iter().zip(&snap.worker_corrected) {
        assert_eq!(state.corrected, p.as_slice());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// One fixed-seed run at a given thread count; returns everything the
/// bit-determinism contract covers (theta, EF states, fabric bit totals).
fn deterministic_run(
    kind: CompressorKind,
    steps: usize,
    threads: usize,
) -> (Vec<f32>, Vec<ef_sgd::coordinator::WorkerState>, (u64, u64, u64)) {
    let (workers, theta0, ..) = setup(4, WorkerMode::ErrorFeedback, kind);
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::new(0.05, steps, vec![0.5]),
        threads,
        ..Default::default()
    };
    let mut driver = TrainDriver::new(cfg, workers, theta0);
    let mut rec = ef_sgd::metrics::Recorder::new();
    for _ in 0..steps {
        driver.round(&mut rec);
    }
    let snap = driver.snapshot();
    let states = driver.worker_states();
    (snap.theta, states, driver_traffic(&driver))
}

/// Assert bit-identity of a compressor's training run across thread
/// counts — this covers both the worker pool AND the leader's parallel
/// decode fan-out (the fixed-group partial-sum reduction must not depend
/// on how many threads decoded the frames).
fn assert_threads_bit_deterministic(kind: CompressorKind, steps: usize) {
    let (theta1, states1, bits1) = deterministic_run(kind, steps, 1);
    for threads in [2usize, 4] {
        let (theta_n, states_n, bits_n) = deterministic_run(kind, steps, threads);
        // exact equality, not tolerance: the engine promises bit-identity
        assert_eq!(theta1, theta_n, "theta differs at threads={threads}");
        assert_eq!(bits1, bits_n, "bit totals differ at threads={threads}");
        for (a, b) in states1.iter().zip(&states_n) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.error, b.error, "residual differs at threads={threads}");
            assert_eq!(
                a.corrected, b.corrected,
                "corrected grad differs at threads={threads}"
            );
        }
    }
}

/// The parallel engine is bit-deterministic: with a fixed seed, final
/// parameters, every EF residual, and the fabric's bit totals are
/// identical for any `threads` value (the `--threads` CLI knob).
#[test]
fn threads_are_bit_deterministic() {
    assert_threads_bit_deterministic(CompressorKind::ScaledSign, 40);
}

/// Same contract with the QSGD compressor, whose Elias-packed frames are
/// variable-length: parallel decode + fused accumulation must reproduce
/// theta, residuals, AND the exact wire bit totals at any thread count.
/// (Fewer steps than the scaled-sign run: EF around an *unscaled*
/// unbiased quantizer grows the residual geometrically — Remark 5 is why
/// the 1/k scaling exists — and the test must stay far from f32 range.)
#[test]
fn qsgd_threads_are_bit_deterministic() {
    assert_threads_bit_deterministic(CompressorKind::Qsgd, 20);
}

/// QSGD's Elias wire pack is dramatically smaller than the dense f32
/// frames it used to travel in (the comm experiment's QSGD rows are now
/// honest): push traffic is at least 4x below an identical run with
/// dense-encoded identity compression.
#[test]
fn qsgd_push_traffic_beats_dense_by_4x() {
    let run = |mode, kind| {
        let (workers, theta0, ..) = setup(2, mode, kind);
        let cfg = DriverConfig {
            steps: 6,
            schedule: LrSchedule::constant(0.05),
            update_rule: if mode == WorkerMode::DenseGrad {
                UpdateRule::ScaleByLr
            } else {
                UpdateRule::ApplyAggregate
            },
            ..Default::default()
        };
        TrainDriver::new(cfg, workers, theta0)
            .run()
            .traffic
            .bits_of_kind(MessageKind::GradPush)
    };
    let dense = run(WorkerMode::DenseGrad, CompressorKind::None);
    let qsgd = run(WorkerMode::ErrorFeedback, CompressorKind::Qsgd);
    let ratio = dense as f64 / qsgd as f64;
    assert!(ratio > 4.0, "qsgd push compression ratio {ratio}");
}

fn driver_traffic(driver: &TrainDriver) -> (u64, u64, u64) {
    let stats = driver.traffic();
    (
        stats.total_bits,
        stats.bits_of_kind(MessageKind::GradPush),
        stats.bits_of_kind(MessageKind::ParamBroadcast),
    )
}

/// Regression for the checkpoint-restore bug: a worker restored from a
/// mid-run checkpoint must produce a next wire frame byte-identical to the
/// uninterrupted run's frame (the scaled-sign scale reads the corrected
/// gradient, so EF state must round-trip completely).
#[test]
fn restored_worker_next_frame_byte_identical() {
    let d = 48;
    let mk_worker = || {
        Worker::new(
            0,
            Box::new(ObjectiveSource::new(
                ef_sgd::model::toy::SparseNoiseQuadratic::new(d, 0.0),
                Pcg64::new(21, 3),
            )),
            WorkerMode::ErrorFeedback,
            CompressorKind::ScaledSign,
            8,
            4,
            Pcg64::new(22, 0),
        )
    };
    let thetas: Vec<Vec<f32>> = (0..6)
        .map(|t| (0..d).map(|i| ((i + 7 * t) as f32 * 0.31).sin()).collect())
        .collect();

    // uninterrupted run: 5 steps, then capture the 6th frame
    let mut w1 = mk_worker();
    for theta in &thetas[..5] {
        let _ = w1.step_encode(theta, 0.1);
    }
    let saved = w1.ef_state().save_state();
    let frame_a = w1.step_encode(&thetas[5], 0.1);

    // restored run: fresh worker, load the checkpoint, take the 6th step.
    // (the quadratic gradient is deterministic, so only EF state matters)
    let mut w2 = mk_worker();
    w2.ef_state_mut().load_state(&saved).unwrap();
    let frame_b = w2.step_encode(&thetas[5], 0.1);

    assert_eq!(frame_a.bits, frame_b.bits);
    assert_eq!(frame_a.bytes, frame_b.bytes, "wire frames diverge after restore");
}

#[test]
fn single_worker_driver_equals_local_optimizer() {
    // With one worker, mean aggregation, and EF-scaled-sign the driver's
    // trajectory must equal a local EfSignSgd run on the same grad stream.
    use ef_sgd::model::toy::SparseNoiseQuadratic;
    use ef_sgd::optim::{EfSignSgd, Optimizer};
    let d = 48;
    let steps = 50;
    let mk_src = || {
        ObjectiveSource::new(SparseNoiseQuadratic::new(d, 0.5), Pcg64::new(10, 7))
    };
    let worker = Worker::new(
        0,
        Box::new(mk_src()),
        WorkerMode::ErrorFeedback,
        CompressorKind::ScaledSign,
        8,
        4,
        Pcg64::new(11, 0),
    );
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.07),
        ..Default::default()
    };
    let theta0: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
    let out = TrainDriver::new(cfg, vec![worker], theta0.clone()).run();

    let mut opt = EfSignSgd::new(d, 0.07, Pcg64::seeded(0));
    let mut x = theta0;
    let mut src = mk_src();
    let mut g = vec![0.0f32; d];
    for _ in 0..steps {
        src.grad(&x, &mut g);
        opt.step(&mut x, &g);
    }
    for (a, b) in out.theta.iter().zip(&x) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}
