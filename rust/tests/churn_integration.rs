//! Integration: elastic membership end to end — inactive schedules are
//! byte-identical to the churn-free engine, seeded churn is
//! bit-deterministic across thread counts, checkpoint restore replays
//! membership exactly, and the churn sweep's EF-robustness claim holds.

use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::async_driver::AsyncTrainDriver;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::experiments::{churn, ExpContext};
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::{MembershipSchedule, StragglerModel, StragglerSchedule};
use ef_sgd::util::Pcg64;

fn quadratic_workers(n: usize, d: usize) -> Vec<Worker> {
    (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.5),
                    Pcg64::new(17, 100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                4,
                4,
                Pcg64::new(18, id as u64),
            )
        })
        .collect()
}

fn lognormal(sigma: f64, seed: u64) -> StragglerSchedule {
    StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma }, seed)
}

/// A schedule whose only event fires far beyond the run engages every
/// piece of churn machinery (live-set broadcast, `step_workers`,
/// expected-count gather, epoch bookkeeping) without ever changing the
/// fleet — it must be byte-identical to `none()`, which takes the
/// churn-free fast path. Checked for the sync and async engines at
/// shards 1 and 4.
#[test]
fn inactive_and_far_future_schedules_are_byte_identical() {
    let d = 64;
    let steps = 25;
    let n = 4;
    let far = || MembershipSchedule::parse("leave:1@1000000000").unwrap();
    assert!(far().is_active());
    for shards in [1usize, 4] {
        let cfg = |membership: MembershipSchedule| DriverConfig {
            steps,
            schedule: LrSchedule::constant(0.05),
            straggler: lognormal(1.0, 5),
            shards,
            membership,
            ..Default::default()
        };
        // sync engine
        let run_sync = |membership: MembershipSchedule| {
            let mut drv =
                TrainDriver::new(cfg(membership), quadratic_workers(n, d), vec![1.0f32; d]);
            let mut rec = Recorder::new();
            for _ in 0..steps {
                drv.round(&mut rec);
            }
            let snap = drv.snapshot();
            (snap, drv.traffic().total_bits, drv.sim_time_s())
        };
        let (a, bits_a, sim_a) = run_sync(MembershipSchedule::none());
        let (b, bits_b, sim_b) = run_sync(far());
        assert_eq!(a.theta, b.theta, "sync theta, shards={shards}");
        assert_eq!(a.worker_errors, b.worker_errors, "sync residuals, shards={shards}");
        assert_eq!(a.worker_corrected, b.worker_corrected, "sync corrected, shards={shards}");
        assert_eq!(bits_a, bits_b, "sync wire bits, shards={shards}");
        assert_eq!(sim_a, sim_b, "sync virtual time, shards={shards}");
        assert_eq!(b.epoch, 0, "far-future schedule must never bump the epoch");

        // async engine (quorum 3 of 4, staleness bound 2)
        let run_async = |membership: MembershipSchedule| {
            let mut drv = AsyncTrainDriver::new(
                cfg(membership),
                3,
                2,
                quadratic_workers(n, d),
                vec![1.0f32; d],
            );
            let mut rec = Recorder::new();
            for _ in 0..steps {
                drv.step_round(&mut rec);
            }
            let snap = drv.snapshot();
            (snap, drv.traffic().total_bits, drv.sim_time_s())
        };
        let (a, bits_a, sim_a) = run_async(MembershipSchedule::none());
        let (b, bits_b, sim_b) = run_async(far());
        assert_eq!(a.theta, b.theta, "async theta, shards={shards}");
        assert_eq!(a.worker_errors, b.worker_errors, "async residuals, shards={shards}");
        assert_eq!(a.worker_corrected, b.worker_corrected, "async corrected, shards={shards}");
        assert_eq!(bits_a, bits_b, "async wire bits, shards={shards}");
        assert_eq!(sim_a, sim_b, "async virtual time, shards={shards}");
    }
}

fn churned_sync_run(threads: usize) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, u64, u64, f64) {
    let d = 64;
    let steps = 30;
    let n = 6;
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.05),
        straggler: lognormal(1.0, 11),
        threads,
        // exercises every event kind: fail-stop, graceful leave, warm
        // rejoin, cold join-after-leave, and a departure that never revives
        membership: MembershipSchedule::parse("crash:1@3,leave:2@5,rejoin:1@9,join:2@14,leave:3@20")
            .unwrap(),
        ..Default::default()
    };
    let mut drv = TrainDriver::new(cfg, quadratic_workers(n, d), vec![1.0f32; d]);
    let mut rec = Recorder::new();
    for _ in 0..steps {
        drv.round(&mut rec);
    }
    let snap = drv.snapshot();
    let bits = drv.traffic().total_bits;
    let sim = drv.sim_time_s();
    (snap.theta, snap.worker_errors, snap.worker_corrected, snap.epoch, bits, sim)
}

fn churned_async_run(threads: usize) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, u64, u64, f64) {
    let d = 64;
    let steps = 40;
    let n = 6;
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.05),
        straggler: lognormal(1.5, 11),
        threads,
        membership: MembershipSchedule::parse("crash:1@3,leave:2@5,rejoin:1@9,rejoin:2@14")
            .unwrap(),
        ..Default::default()
    };
    let mut drv = AsyncTrainDriver::new(cfg, 3, 2, quadratic_workers(n, d), vec![1.0f32; d]);
    let mut rec = Recorder::new();
    for _ in 0..steps {
        drv.step_round(&mut rec);
    }
    let snap = drv.snapshot();
    let bits = drv.traffic().total_bits;
    let sim = drv.sim_time_s();
    (snap.theta, snap.worker_errors, snap.worker_corrected, snap.epoch, bits, sim)
}

/// Seeded churn is bit-deterministic for any `--threads` value: the event
/// schedule is a pure function of `(seed, n, round)`, so crash/rejoin
/// cycles yield identical theta, EF states, membership epoch, wire bits
/// AND virtual time at 1 and 4 threads — for both engines.
#[test]
fn seeded_churn_is_bit_deterministic_across_threads() {
    let a = churned_sync_run(1);
    let b = churned_sync_run(4);
    assert_eq!(a, b, "sync churn run differs across thread counts");
    assert!(a.3 > 0, "sync run applied no membership epochs");

    let a = churned_async_run(1);
    let b = churned_async_run(4);
    assert_eq!(a, b, "async churn run differs across thread counts");
    assert!(a.3 > 0, "async run applied no membership epochs");
}

/// Checkpoint restore mid-churn: 10 rounds + snapshot + restore into a
/// fresh driver + 10 rounds must equal 20 straight rounds bit for bit,
/// with membership events falling on both sides of the snapshot — the
/// restore replays the schedule up to the checkpointed round.
#[test]
fn checkpoint_restore_under_churn_resumes_identically() {
    let d = 48;
    let n = 4;
    let sched =
        || MembershipSchedule::parse("crash:1@3,rejoin:1@7,leave:2@12,rejoin:2@16").unwrap();
    let cfg = |steps: usize| DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.05),
        membership: sched(),
        ..Default::default()
    };

    // run A: 20 straight rounds
    let mut a = TrainDriver::new(cfg(20), quadratic_workers(n, d), vec![1.0f32; d]);
    let mut rec = Recorder::new();
    for _ in 0..20 {
        a.round(&mut rec);
    }
    let snap_a = a.snapshot();

    // run B: 10 rounds, snapshot, restore into a fresh driver, 10 more
    let mut b1 = TrainDriver::new(cfg(10), quadratic_workers(n, d), vec![1.0f32; d]);
    let mut rec1 = Recorder::new();
    for _ in 0..10 {
        b1.round(&mut rec1);
    }
    let mid = b1.snapshot();
    assert_eq!(mid.round, 10);
    assert!(mid.epoch > 0, "no membership epoch before the snapshot");

    let mut b2 = TrainDriver::new(cfg(0), quadratic_workers(n, d), vec![1.0f32; d]);
    b2.restore(&mid);
    let mut rec2 = Recorder::new();
    for _ in 0..10 {
        b2.round(&mut rec2);
    }
    let snap_b = b2.snapshot();

    assert_eq!(snap_a.round, snap_b.round);
    assert_eq!(snap_a.epoch, snap_b.epoch, "membership epoch diverged across restore");
    assert_eq!(snap_a.theta, snap_b.theta, "theta diverged across restore");
    assert_eq!(snap_a.worker_errors, snap_b.worker_errors);
    assert_eq!(snap_a.worker_corrected, snap_b.worker_corrected);
}

/// The acceptance claim: under fail-stop churn of any swept rate, EF-SGD
/// stays far below plain SIGNSGD (the residual's robustness survives
/// losing residuals to crashes), and EF's degradation versus its
/// churn-free floor is small on the scale of the sign trap.
#[test]
fn churn_sweep_ef_degrades_gracefully_vs_signsgd() {
    let result = churn::churn(&ExpContext::quick()).unwrap();
    let rec = &result.recorders[0].1;
    let series = |name: &str| -> Vec<f64> { rec.get(name).expect(name).values.clone() };
    let ef = series("final_ef_sign");
    let sign = series("final_signsgd");
    assert_eq!(ef.len(), churn::RATES.len());
    assert_eq!(sign.len(), churn::RATES.len());
    for (i, (e, s)) in ef.iter().zip(&sign).enumerate() {
        // the sign trap dominates churn: EF lands > 4x below plain sign
        // at every crash rate, so signSGD's loss gap versus EF stays
        // strictly large everywhere in the sweep
        assert!(e * 4.0 < *s, "rate #{i}: ef {e} not well below sign {s}");
    }
    for i in 1..ef.len() {
        // graceful degradation: losing residuals to crashes moves EF by
        // at most a sliver of the trap scale (signSGD's churn-free loss)
        let deg_ef = ef[i] - ef[0];
        assert!(
            deg_ef < sign[0] * 0.25,
            "rate #{i}: EF degradation {deg_ef} not small vs trap scale {}",
            sign[0]
        );
    }
    // the sweep is not vacuous: the harshest rate actually churned
    let events = series("events_ef_sign");
    assert!(events.last().unwrap() > &0.0, "no membership events at the top rate");
}
