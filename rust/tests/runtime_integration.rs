//! Integration: the PJRT runtime executes the AOT artifacts and the
//! numerics match the Rust-native references (the L1/L2 <-> L3 contract).
//!
//! These tests are skipped (with a message) when `make artifacts` has not
//! been run — `make test` always builds artifacts first.

use ef_sgd::compress::{ErrorFeedback, ScaledSign};
use ef_sgd::data::tokens::MarkovCorpus;
use ef_sgd::runtime::{LmSession, Runtime};
use ef_sgd::tensor;
use ef_sgd::util::Pcg64;

fn open_tiny() -> Option<(Runtime, LmSession)> {
    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e}");
            return None;
        }
    };
    let session = LmSession::open(&rt, "tiny").expect("open tiny session");
    Some((rt, session))
}

fn randn(d: usize, seed: u64, std: f64) -> Vec<f32> {
    let mut rng = Pcg64::seeded(seed);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 0.0, std);
    v
}

#[test]
fn ef_sign_artifact_matches_rust_reference() {
    let Some((_rt, session)) = open_tiny() else { return };
    let d = session.d();
    let g = randn(d, 1, 1.0);
    let e = randn(d, 2, 0.5);
    let gamma = 0.1f32;
    let (delta, e_new) = session.ef_sign(&g, &e, gamma).unwrap();

    // rust-native reference: p = gamma g + e; delta = scaled_sign(p); e' = p - delta
    let mut ef = ErrorFeedback::new(d, Box::new(ScaledSign));
    let p0 = vec![0.0f32; d];
    ef.set_state(0, &e, &p0);
    let mut rng = Pcg64::seeded(0);
    let delta_ref = {
        let mut out = vec![0.0f32; d];
        ef.step_into(gamma, &g, &mut out, &mut rng);
        out
    };
    assert!(
        tensor::rel_l2(&delta, &delta_ref) < 1e-3,
        "delta mismatch {}",
        tensor::rel_l2(&delta, &delta_ref)
    );
    assert!(tensor::rel_l2(&e_new, ef.error()) < 1e-3);
    // exact invariant: delta + e' == gamma g + e
    for i in 0..d {
        let p = gamma * g[i] + e[i];
        assert!((delta[i] + e_new[i] - p).abs() < 1e-4);
    }
}

#[test]
fn density_artifact_matches_rust() {
    let Some((_rt, session)) = open_tiny() else { return };
    let d = session.d();
    for seed in [3u64, 4, 5] {
        let v = randn(d, seed, 2.0);
        let phi_pjrt = session.density(&v).unwrap();
        let phi_rust = tensor::density(&v);
        assert!(
            (phi_pjrt - phi_rust).abs() < 1e-4,
            "{phi_pjrt} vs {phi_rust}"
        );
    }
}

#[test]
fn lm_step_loss_near_uniform_at_init_and_grad_finite() {
    let Some((rt, session)) = open_tiny() else { return };
    let theta = rt.init_params(&session.model).unwrap();
    let corpus = MarkovCorpus::new(session.model.vocab, 3, 0);
    let (b, s) = session.model.token_shape();
    let mut rng = Pcg64::seeded(7);
    let tokens = corpus.sample_batch(b, s, &mut rng);
    let (loss, grad) = session.train_step(&theta, &tokens).unwrap();
    let uniform = (session.model.vocab as f64).ln();
    assert!((loss - uniform).abs() < 0.5, "loss {loss} vs ln V {uniform}");
    assert!(grad.iter().all(|v| v.is_finite()));
    assert!(tensor::norm2(&grad) > 0.0);
    // eval on the same tokens equals the train loss
    let eval = session.eval(&theta, &tokens).unwrap();
    assert!((eval - loss).abs() < 1e-4);
}

#[test]
fn fused_step_consistent_with_parts() {
    let Some((rt, session)) = open_tiny() else { return };
    let d = session.d();
    let theta = rt.init_params(&session.model).unwrap();
    let e = randn(d, 8, 0.01);
    let corpus = MarkovCorpus::new(session.model.vocab, 3, 1);
    let (b, s) = session.model.token_shape();
    let mut rng = Pcg64::seeded(9);
    let tokens = corpus.sample_batch(b, s, &mut rng);
    let gamma = 0.2f32;

    let (loss_f, delta_f, enew_f) = session.train_step_ef(&theta, &e, &tokens, gamma).unwrap();
    let (loss_p, grad) = session.train_step(&theta, &tokens).unwrap();
    let (delta_p, enew_p) = session.ef_sign(&grad, &e, gamma).unwrap();

    assert!((loss_f - loss_p).abs() < 1e-5);
    assert!(tensor::rel_l2(&delta_f, &delta_p) < 1e-3);
    assert!(tensor::rel_l2(&enew_f, &enew_p) < 1e-3);
}

#[test]
fn apply_update_artifact() {
    let Some((_rt, session)) = open_tiny() else { return };
    let d = session.d();
    let theta = randn(d, 10, 1.0);
    let delta = randn(d, 11, 0.1);
    let out = session.apply_update(&theta, &delta).unwrap();
    for i in 0..d {
        assert!((out[i] - (theta[i] - delta[i])).abs() < 1e-6);
    }
}

#[test]
fn topk_artifact_threshold_semantics() {
    let Some((rt, session)) = open_tiny() else { return };
    let d = session.d();
    let k = rt.model("tiny").unwrap().topk_k;
    let g = randn(d, 12, 1.0);
    let e = vec![0.0f32; d];
    let (delta, e_new) = session.ef_topk(&g, &e, 1.0).unwrap();
    let nz = delta.iter().filter(|v| **v != 0.0).count();
    assert!(nz >= k && nz <= k + 8, "kept {nz} vs k {k}");
    // kept coords preserve value; identity delta + e' = p
    for i in 0..d {
        assert!((delta[i] + e_new[i] - g[i]).abs() < 1e-5);
        assert!(delta[i] == 0.0 || (delta[i] - g[i]).abs() < 1e-6);
    }
}

#[test]
fn a_few_training_steps_reduce_loss() {
    let Some((rt, session)) = open_tiny() else { return };
    let mut theta = rt.init_params(&session.model).unwrap();
    let d = session.d();
    let corpus = MarkovCorpus::new(session.model.vocab, 3, 2);
    let (b, s) = session.model.token_shape();
    let mut rng = Pcg64::seeded(13);
    let mut e = vec![0.0f32; d];
    // The tiny LM learns gradually (4 x 32 tokens/step); assert a clear
    // downward trend rather than a large absolute drop.
    let mut losses = Vec::new();
    for _ in 0..350 {
        let tokens = corpus.sample_batch(b, s, &mut rng);
        let (loss, delta, e_new) = session.train_step_ef(&theta, &e, &tokens, 0.5).unwrap();
        tensor::sub_assign(&mut theta, &delta);
        e = e_new;
        losses.push(loss);
    }
    let head = ef_sgd::util::stats::mean(&losses[..50]);
    let tail = ef_sgd::util::stats::mean(&losses[300..]);
    assert!(tail < head - 0.02, "loss head {head} -> tail {tail}");
}

#[test]
fn executable_cache_hits() {
    let Some((rt, _session)) = open_tiny() else { return };
    let n = rt.compiled_count();
    // reopening the session must not recompile anything
    let _again = LmSession::open(&rt, "tiny").unwrap();
    assert_eq!(rt.compiled_count(), n);
}
