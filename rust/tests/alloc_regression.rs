//! Steady-state allocation regression: after the warm-up rounds, a full
//! synchronous training round performs ZERO heap allocations — counted
//! process-wide, across the driver thread and every pool thread — for
//! shards ∈ {1, 4} × threads ∈ {1, 4}. This pins the zero-copy fabric /
//! pooled-buffer architecture of docs/PERF.md: Arc-shared broadcasts
//! refreshed in place, frame buffers cycling through the fabric's
//! `FramePool`, ring-buffer pool channels, and recycled decode partials.
//! The flight recorder (fixed-capacity rings) and the metrics registry
//! (fixed-slot atomics) are enabled too, so observability is covered by
//! the same zero-allocation contract.
//!
//! This file intentionally contains a single #[test]: the counting
//! allocator is process-global, and a concurrently running sibling test
//! would pollute the measurement window.

use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::obs::{RunMetrics, DEFAULT_RING_CAPACITY};
use ef_sgd::util::alloc_count::{self, CountingAllocator};
use ef_sgd::util::Pcg64;
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn make_driver(n: usize, d: usize, shards: usize, threads: usize) -> TrainDriver {
    let workers: Vec<Worker> = (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::seeded(100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                4,
                4,
                Pcg64::seeded(id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps: 0, // rounds are driven manually
        schedule: LrSchedule::constant(0.05),
        threads,
        shards,
        // the flight recorder and metrics registry run at full tilt here:
        // their hot paths (indexed ring writes, relaxed atomics) must also
        // be allocation-free in the steady state
        trace_capacity: DEFAULT_RING_CAPACITY,
        metrics: Some(Arc::new(RunMetrics::new(n))),
        ..Default::default()
    };
    TrainDriver::new(cfg, workers, vec![1.0f32; d])
}

#[test]
fn steady_state_rounds_do_not_allocate() {
    // d divisible by every shard count under test, so the recycled frame
    // buffers and decode partials stabilize at one capacity per shape
    // (a ragged split would make shard slices differ and reshuffle pooled
    // capacities between rounds).
    let d = 1024;
    let n = 4;
    for &(shards, threads) in &[(1usize, 1usize), (1, 4), (4, 1), (4, 4)] {
        let mut driver = make_driver(n, d, shards, threads);
        let mut rec = Recorder::new();
        // Rounds 1-2 warm every pool: frame buffers, channel rings, inbox
        // deques, broadcast Arcs, decode partials, recorder series, and
        // the traffic-accounting map entries.
        driver.round(&mut rec);
        driver.round(&mut rec);
        // the recorder's series grow amortized; give the measurement
        // window pre-reserved headroom
        rec.reserve_all(16);
        let before = alloc_count::allocs();
        for _ in 0..3 {
            driver.round(&mut rec);
        }
        let after = alloc_count::allocs();
        assert_eq!(
            after - before,
            0,
            "shards={shards} threads={threads}: {} steady-state allocation(s) \
             in 3 rounds (leader hot path must be allocation-free)",
            after - before
        );
        // sanity: the rounds actually ran and trained
        assert_eq!(driver.rounds(), 5);
        assert!(driver.theta().iter().all(|v| v.is_finite()));
    }
}
