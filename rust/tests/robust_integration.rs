//! Integration: Byzantine-robust aggregation end to end — bit-determinism
//! of adversarial runs over (shards × threads), the `--adversary none` /
//! trim-0 byte-identity contract against the plain-mean engine, and
//! graceful degradation (drop + count, never panic) when an adversary
//! scribbles undecodable bytes over the wire.

use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::{Aggregation, LrSchedule};
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::AdversarySchedule;
use ef_sgd::util::Pcg64;

const D: usize = 97; // ragged shard split on purpose
const N: usize = 8; // signflip:0.25 -> exactly 2 Byzantine workers
const STEPS: usize = 10;
const SEED: u64 = 40;

fn quadratic_workers(kind: CompressorKind) -> Vec<Worker> {
    (0..N)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(D, 0.0),
                    Pcg64::new(SEED, 100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                kind,
                4,
                4,
                Pcg64::new(SEED + 1, id as u64),
            )
        })
        .collect()
}

struct RunOut {
    theta: Vec<f32>,
    errors: Vec<Vec<f32>>,
    corrected: Vec<Vec<f32>>,
    total_bits: u64,
    dropped: u64,
}

fn run(
    kind: CompressorKind,
    aggregation: Aggregation,
    adversary: &str,
    shards: usize,
    threads: usize,
) -> RunOut {
    let cfg = DriverConfig {
        steps: STEPS,
        schedule: LrSchedule::constant(0.05),
        aggregation,
        adversary: AdversarySchedule::parse_spec(adversary, SEED).expect("valid spec"),
        shards,
        threads,
        ..Default::default()
    };
    let mut driver = TrainDriver::new(cfg, quadratic_workers(kind), vec![1.0f32; D]);
    let mut rec = Recorder::new();
    for _ in 0..STEPS {
        driver.round(&mut rec);
    }
    let snap = driver.snapshot();
    let t = driver.traffic();
    RunOut {
        theta: snap.theta,
        errors: snap.worker_errors,
        corrected: snap.worker_corrected,
        total_bits: t.total_bits,
        dropped: t.dropped(),
    }
}

/// Adversarial runs are bit-deterministic: with 25% sign-flippers live on
/// the wire, the trained parameters, every EF tensor, and the exact wire
/// bits are identical across thread counts for S ∈ {1, 4}, for both
/// fixed-length (scaled-sign) and variable-length (QSGD) frames, under
/// both robust combine rules.
#[test]
fn adversarial_robust_runs_are_bit_deterministic() {
    for kind in [CompressorKind::ScaledSign, CompressorKind::Qsgd] {
        for agg in [Aggregation::Median, Aggregation::TrimmedMean(1)] {
            for shards in [1usize, 4] {
                let a = run(kind, agg, "signflip:0.25", shards, 1);
                let b = run(kind, agg, "signflip:0.25", shards, 4);
                let tag = format!("{kind:?}/{agg:?} S={shards}");
                assert_eq!(a.theta, b.theta, "{tag}: theta differs across threads");
                assert_eq!(a.errors, b.errors, "{tag}: residuals differ");
                assert_eq!(a.corrected, b.corrected, "{tag}: corrected differ");
                assert_eq!(a.total_bits, b.total_bits, "{tag}: wire bits differ");
                // sign-flipped frames stay decodable — nothing may drop
                assert_eq!(a.dropped, 0, "{tag}: spurious frame drops");
            }
        }
    }
}

/// The no-adversary contract: `--adversary none`, a parsed `signflip:0`
/// (zero Byzantine workers), and `trimmed:0` (the robust kernel with an
/// empty trim budget) all replay the plain-mean engine byte for byte.
#[test]
fn inactive_adversary_and_trim_zero_replay_the_mean_engine() {
    for kind in [CompressorKind::ScaledSign, CompressorKind::Qsgd] {
        let base = run(kind, Aggregation::Mean, "none", 1, 1);
        let zero_frac = run(kind, Aggregation::Mean, "signflip:0", 1, 1);
        let trim0 = run(kind, Aggregation::TrimmedMean(0), "none", 1, 1);
        for (name, other) in [("signflip:0", &zero_frac), ("trimmed:0", &trim0)] {
            assert_eq!(base.theta, other.theta, "{kind:?}/{name}: theta differs");
            assert_eq!(base.errors, other.errors, "{kind:?}/{name}: residuals differ");
            assert_eq!(base.corrected, other.corrected, "{kind:?}/{name}: corrected differ");
            assert_eq!(base.total_bits, other.total_bits, "{kind:?}/{name}: wire bits differ");
        }
        assert_eq!(base.dropped, 0);
    }
}

/// Random-bytes scribbling over variable-length QSGD frames produces
/// undecodable payloads: the hardened wire path drops and counts them
/// (no panic), the surviving honest frames still train, and the final
/// parameters stay finite.
#[test]
fn scribbled_frames_are_dropped_counted_and_survivable() {
    let out = run(CompressorKind::Qsgd, Aggregation::Mean, "randombytes:0.25", 1, 2);
    assert!(out.dropped > 0, "scribbled QSGD frames should be undecodable and counted");
    assert!(
        out.theta.iter().all(|x| x.is_finite()),
        "surviving honest frames must keep theta finite"
    );
    // the drop path is deterministic too
    let again = run(CompressorKind::Qsgd, Aggregation::Mean, "randombytes:0.25", 1, 4);
    assert_eq!(out.theta, again.theta);
    assert_eq!(out.dropped, again.dropped);
}
