//! CLI entry point for `detlint`. See `docs/LINTS.md` for the rule catalog.
//!
//! Usage:
//!
//! ```text
//! detlint [PATHS...] [--deny-all] [--json] [--quiet]
//!         [--allow RULE] [--critical MOD1,MOD2,...]
//! ```
//!
//! Exit codes: 0 = clean (or findings without `--deny-all`), 1 = unwaived
//! findings under `--deny-all`, 2 = usage / IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use detlint::{scan_paths, Config, Rule};

const USAGE: &str = "usage: detlint [PATHS...] [--deny-all] [--json] [--quiet] \
                     [--allow RULE] [--critical MOD1,MOD2,...]";

fn main() -> ExitCode {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut deny_all = false;
    let mut json = false;
    let mut quiet = false;
    let mut cfg = Config::default();

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--deny-all" => deny_all = true,
            "--json" => json = true,
            "--quiet" => quiet = true,
            "--allow" => match args.next().as_deref().and_then(Rule::parse) {
                Some(rule) => cfg.disabled.push(rule),
                None => {
                    eprintln!("detlint: --allow expects one of D1,D2,D3,H1,U1");
                    return ExitCode::from(2);
                }
            },
            "--critical" => match args.next() {
                Some(mods) => {
                    cfg.critical_modules = mods.split(',').map(|m| m.trim().to_string()).collect();
                }
                None => {
                    eprintln!("detlint: --critical expects a comma-separated module list");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with('-') => {
                eprintln!("detlint: unknown flag `{flag}`\n{USAGE}");
                return ExitCode::from(2);
            }
            path => paths.push(PathBuf::from(path)),
        }
    }
    if paths.is_empty() {
        paths.push(PathBuf::from("rust/src"));
    }

    let report = match scan_paths(&paths, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", report.to_json());
    } else if !quiet {
        for f in &report.findings {
            match &f.waived {
                Some(reason) => println!(
                    "{}:{}: [{}] waived ({}) — {}",
                    f.file.display(),
                    f.line,
                    f.rule,
                    reason,
                    f.message
                ),
                None => println!(
                    "{}:{}: [{}] {}",
                    f.file.display(),
                    f.line,
                    f.rule,
                    f.message
                ),
            }
        }
        println!(
            "detlint: {} file(s) scanned, {} unwaived finding(s), {} waived",
            report.files_scanned,
            report.unwaived_count(),
            report.waived_count()
        );
    }

    if deny_all && report.unwaived_count() > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
