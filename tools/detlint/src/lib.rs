//! `detlint` — the workspace's determinism & hot-path lint pass.
//!
//! A dependency-free (std-only, no `syn`) token-level scanner over Rust
//! sources. It enforces, statically and on every CI run, the two invariants
//! every PR so far has protected only dynamically: bit-determinism of the
//! training trajectory across any `(shards, threads, async)` combination
//! (replay tests) and the allocation-free steady-state round
//! (`alloc_regression`). The full rule catalog, the motivating invariant
//! behind each rule, and the annotation syntax live in `docs/LINTS.md`.
//!
//! Rules:
//!
//! * **D1** — no unordered `HashMap`/`HashSet` or `std::sync::mpsc` in
//!   determinism-critical modules (`coordinator`, `collectives`,
//!   `compress`, `net`, `runtime`); require `BTreeMap`/`BTreeSet` or a
//!   sorted drain.
//! * **D2** — no `Instant::now`/`SystemTime` outside functions annotated
//!   `// detlint: profiling`, so virtual-clock (`simclock`) paths can never
//!   observe wall time.
//! * **D3** — no f32 reduction idioms (`.sum::<f32>()`, f32 `fold`, a
//!   `: f32` binding fed by `.sum()`) outside the approved fused kernels
//!   (`wire.rs`, `aggregate.rs`), protecting the fixed reduction trees.
//! * **H1** — no allocating constructs (`Vec::new`, `vec![]`, `to_vec`,
//!   `collect`, `format!`, `.clone()`, …) inside functions annotated
//!   `// detlint: hot`, complementing the dynamic `alloc_regression` test.
//! * **U1** — every line containing `unsafe` must carry a `// SAFETY:`
//!   comment (same line or the contiguous comment block directly above).
//!
//! Escape hatch: `// detlint: allow(RULE, …) — reason` on the finding's
//! line (trailing comment) or on a comment line directly above it. `all`
//! waives every rule. Waived findings stay in the report, marked.
//!
//! The scanner strips comments and string/char literals before matching,
//! skips `#[cfg(test)]` items entirely, and tracks `fn` bodies by brace
//! depth — it is a lexer, not a parser, so the rules are deliberately
//! conservative token patterns with the `allow` hatch for sanctioned uses.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Component, Path, PathBuf};

/// The rule families detlint enforces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    /// Unordered collections / mpsc in determinism-critical modules.
    D1,
    /// Wall-clock reads outside profiling-annotated regions.
    D2,
    /// f32 reduction idioms outside the approved fused kernels.
    D3,
    /// Allocating constructs inside `// detlint: hot` functions.
    H1,
    /// `unsafe` without a `// SAFETY:` comment.
    U1,
}

impl Rule {
    pub const ALL: [Rule; 5] = [Rule::D1, Rule::D2, Rule::D3, Rule::H1, Rule::U1];

    pub fn name(self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::H1 => "H1",
            Rule::U1 => "U1",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        match s.trim().to_ascii_uppercase().as_str() {
            "D1" => Some(Rule::D1),
            "D2" => Some(Rule::D2),
            "D3" => Some(Rule::D3),
            "H1" => Some(Rule::H1),
            "U1" => Some(Rule::U1),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One lint finding. `waived` carries the `detlint: allow` reason when the
/// finding was explicitly waived at the site.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
    pub waived: Option<String>,
}

/// Scanner configuration. The defaults encode this workspace's policy;
/// every list is overridable from the CLI.
#[derive(Clone, Debug)]
pub struct Config {
    /// Path components that mark a file as determinism-critical (D1).
    pub critical_modules: Vec<String>,
    /// File names whose f32 reductions are the approved fused kernels (D3).
    pub approved_reduction_files: Vec<String>,
    /// Rules switched off entirely.
    pub disabled: Vec<Rule>,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            critical_modules: ["coordinator", "collectives", "compress", "net", "runtime"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            approved_reduction_files: vec!["wire.rs".to_string(), "aggregate.rs".to_string()],
            disabled: Vec::new(),
        }
    }
}

impl Config {
    fn enabled(&self, rule: Rule) -> bool {
        !self.disabled.contains(&rule)
    }

    fn is_critical(&self, path: &Path) -> bool {
        path.components().any(|c| match c {
            Component::Normal(os) => self
                .critical_modules
                .iter()
                .any(|m| os.to_str() == Some(m.as_str())),
            _ => false,
        })
    }

    fn is_approved_reduction_file(&self, path: &Path) -> bool {
        path.file_name()
            .and_then(|f| f.to_str())
            .is_some_and(|name| self.approved_reduction_files.iter().any(|a| a == name))
    }
}

/// A whole-scan report.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn unwaived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waived.is_none())
    }

    pub fn unwaived_count(&self) -> usize {
        self.unwaived().count()
    }

    pub fn waived_count(&self) -> usize {
        self.findings.len() - self.unwaived_count()
    }

    pub fn count_of(&self, rule: Rule) -> usize {
        self.unwaived().filter(|f| f.rule == rule).count()
    }

    /// Machine-readable report (hand-rolled JSON; no serde offline).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"tool\": \"detlint\",\n");
        out.push_str(&format!(
            "  \"files_scanned\": {},\n  \"unwaived\": {},\n  \"waived\": {},\n",
            self.files_scanned,
            self.unwaived_count(),
            self.waived_count()
        ));
        out.push_str("  \"counts\": {");
        for (i, rule) in Rule::ALL.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\": {}", rule, self.count_of(*rule)));
        }
        out.push_str("},\n  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                 \"message\": \"{}\", \"waived\": {}, \"waive_reason\": {}}}{}\n",
                f.rule,
                json_escape(&f.file.display().to_string()),
                f.line,
                json_escape(&f.message),
                f.waived.is_some(),
                match &f.waived {
                    Some(r) => format!("\"{}\"", json_escape(r)),
                    None => "null".to_string(),
                },
                if i + 1 == self.findings.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}");
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Lexing: split a source file into per-line code (strings/chars blanked,
// comments removed) and per-line comment texts (for annotations).
// ---------------------------------------------------------------------------

#[derive(Default)]
struct SrcLine {
    code: String,
    comments: Vec<String>,
}

enum LexState {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Strip `src` into lines of pure code + collected comments. String and
/// char literal *contents* are dropped (their delimiters are kept so tokens
/// never fuse across a removed literal); line and block comments are
/// captured per line for annotation parsing.
fn strip_lines(src: &str) -> Vec<SrcLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<SrcLine> = vec![SrcLine::default()];
    let mut comment = String::new();
    let mut state = LexState::Code;
    let mut prev_code: char = ' ';
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            match state {
                LexState::LineComment => {
                    lines.last_mut().unwrap().comments.push(comment.clone());
                    comment.clear();
                    state = LexState::Code;
                }
                LexState::BlockComment(_) => {
                    lines.last_mut().unwrap().comments.push(comment.clone());
                    comment.clear();
                }
                _ => {}
            }
            lines.push(SrcLine::default());
            prev_code = ' ';
            i += 1;
            continue;
        }
        match state {
            LexState::Code => {
                let next = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && next == '/' {
                    state = LexState::LineComment;
                    i += 2;
                } else if c == '/' && next == '*' {
                    state = LexState::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    lines.last_mut().unwrap().code.push('"');
                    state = LexState::Str;
                    prev_code = '"';
                    i += 1;
                } else if (c == 'r' || c == 'b') && !is_ident_char(prev_code) {
                    // Possible raw / byte string or byte char. Count the
                    // `r#…"` shape; anything else falls through as code.
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    let raw = c == 'r' || chars.get(i + 1) == Some(&'r');
                    if chars.get(j) == Some(&'"') && (raw || hashes == 0) {
                        lines.last_mut().unwrap().code.push('"');
                        state = if raw {
                            LexState::RawStr(hashes)
                        } else {
                            LexState::Str
                        };
                        prev_code = '"';
                        i = j + 1;
                    } else if c == 'b' && chars.get(i + 1) == Some(&'\'') {
                        // byte char literal b'x' / b'\n'
                        i = skip_char_literal(&chars, i + 1);
                        prev_code = '\'';
                    } else {
                        lines.last_mut().unwrap().code.push(c);
                        prev_code = c;
                        i += 1;
                    }
                } else if c == '\'' {
                    let n1 = chars.get(i + 1).copied();
                    let n2 = chars.get(i + 2).copied();
                    if n1 == Some('\\') || (n2 == Some('\'') && n1 != Some('\'')) {
                        i = skip_char_literal(&chars, i);
                        prev_code = '\'';
                    } else {
                        // lifetime marker: drop the quote, keep going
                        prev_code = '\'';
                        i += 1;
                    }
                } else {
                    lines.last_mut().unwrap().code.push(c);
                    prev_code = c;
                    i += 1;
                }
            }
            LexState::LineComment => {
                comment.push(c);
                i += 1;
            }
            LexState::BlockComment(depth) => {
                let next = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '*' && next == '/' {
                    if depth == 1 {
                        lines.last_mut().unwrap().comments.push(comment.clone());
                        comment.clear();
                        state = LexState::Code;
                    } else {
                        state = LexState::BlockComment(depth - 1);
                    }
                    i += 2;
                } else if c == '/' && next == '*' {
                    state = LexState::BlockComment(depth + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            LexState::Str => {
                if c == '\\' {
                    // keep `\<newline>` continuations on the line counter
                    if chars.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    lines.last_mut().unwrap().code.push('"');
                    state = LexState::Code;
                    prev_code = '"';
                    i += 1;
                } else {
                    i += 1;
                }
            }
            LexState::RawStr(hashes) => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut seen = 0u32;
                    while seen < hashes && chars.get(j) == Some(&'#') {
                        seen += 1;
                        j += 1;
                    }
                    if seen == hashes {
                        lines.last_mut().unwrap().code.push('"');
                        state = LexState::Code;
                        prev_code = '"';
                        i = j;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    if !comment.is_empty() {
        lines.last_mut().unwrap().comments.push(comment);
    }
    lines
}

/// Skip a char literal starting at the opening quote `chars[start] == '\''`;
/// returns the index just past the closing quote.
fn skip_char_literal(chars: &[char], start: usize) -> usize {
    let mut j = start + 1;
    while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
        if chars[j] == '\\' {
            j += 1;
        }
        j += 1;
    }
    (j + 1).min(chars.len())
}

// ---------------------------------------------------------------------------
// Annotations
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct AllowSpec {
    all: bool,
    rules: Vec<Rule>,
    reason: String,
}

impl AllowSpec {
    fn applies(&self, rule: Rule) -> bool {
        self.all || self.rules.contains(&rule)
    }
}

enum Marker {
    Hot,
    Profiling,
    Allow(AllowSpec),
}

/// Parse every `detlint:` marker out of one comment's text.
fn parse_markers(comment: &str) -> Vec<Marker> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find("detlint:") {
        let after = &rest[pos + "detlint:".len()..];
        let t = after.trim_start();
        if t.starts_with("hot") {
            out.push(Marker::Hot);
        } else if t.starts_with("profiling") {
            out.push(Marker::Profiling);
        } else if let Some(spec) = t.strip_prefix("allow") {
            if let Some(body) = spec.trim_start().strip_prefix('(') {
                if let Some(close) = body.find(')') {
                    let mut all = false;
                    let mut rules = Vec::new();
                    for part in body[..close].split(',') {
                        let p = part.trim();
                        if p.eq_ignore_ascii_case("all") {
                            all = true;
                        } else if let Some(r) = Rule::parse(p) {
                            rules.push(r);
                        }
                    }
                    let reason = body[close + 1..]
                        .trim_matches(|c: char| {
                            c.is_whitespace() || c == '—' || c == '-' || c == ':'
                        })
                        .to_string();
                    out.push(Marker::Allow(AllowSpec { all, rules, reason }));
                }
            }
        }
        rest = after;
    }
    out
}

// ---------------------------------------------------------------------------
// Matching helpers
// ---------------------------------------------------------------------------

/// Count identifier-bounded occurrences of `ident` in `code`.
fn count_ident(code: &str, ident: &str) -> usize {
    let bytes = code.as_bytes();
    let mut count = 0;
    let mut from = 0;
    while let Some(pos) = code[from..].find(ident) {
        let p = from + pos;
        let end = p + ident.len();
        let before_ok = p == 0 || !is_ident_char(bytes[p - 1] as char);
        let after_ok = end >= bytes.len() || !is_ident_char(bytes[end] as char);
        if before_ok && after_ok {
            count += 1;
        }
        from = end;
    }
    count
}

/// Count occurrences of `pat` in `hay`; when `bound_start` is set, the
/// character before the match must not be an identifier character.
fn count_sub(hay: &str, pat: &str, bound_start: bool) -> usize {
    let bytes = hay.as_bytes();
    let mut count = 0;
    let mut from = 0;
    while let Some(pos) = hay[from..].find(pat) {
        let p = from + pos;
        if !bound_start || p == 0 || !is_ident_char(bytes[p - 1] as char) {
            count += 1;
        }
        from = p + pat.len();
    }
    count
}

/// Allocating constructs H1 bans inside `// detlint: hot` functions.
/// Matched against whitespace-stripped code; `(name, pattern, bounded)`.
const H1_PATTERNS: &[(&str, &str, bool)] = &[
    ("Vec::new", "Vec::new", true),
    ("vec![]", "vec!", true),
    ("to_vec", ".to_vec(", false),
    ("collect", ".collect(", false),
    ("collect", ".collect::", false),
    ("format!", "format!", true),
    (".clone()", ".clone(", false),
    ("Box::new", "Box::new", true),
    ("String::new", "String::new", true),
    ("String::from", "String::from", true),
    ("to_string", ".to_string(", false),
    ("to_owned", ".to_owned(", false),
];

// ---------------------------------------------------------------------------
// The scan
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum RegionKind {
    Hot,
    Profiling,
    CfgTest,
}

struct Region {
    kind: RegionKind,
    /// Brace depth just before the region's opening `{`.
    open_depth: i64,
}

/// Scan one file's source. `file` is used for path-based policy (critical
/// modules, approved kernels) and finding locations.
pub fn scan_source(file: &Path, src: &str, cfg: &Config) -> Vec<Finding> {
    let lines = strip_lines(src);
    let critical = cfg.is_critical(file);
    let approved_d3 = cfg.is_approved_reduction_file(file);
    let mut findings: Vec<Finding> = Vec::new();

    let mut depth: i64 = 0;
    let mut paren: i64 = 0;
    let mut regions: Vec<Region> = Vec::new();
    // annotation seen; waiting for the item keyword it applies to
    let mut pending: Option<RegionKind> = None;
    // item keyword seen; the next top-level `{` opens the region
    let mut awaiting: Option<RegionKind> = None;
    // allows on comment-only lines carry to the next code line
    let mut carried: Vec<AllowSpec> = Vec::new();
    // `SAFETY:` seen in the contiguous comment block above the next code line
    let mut safety_above = false;

    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let has_code = !line.code.trim().is_empty();

        // -- annotations ---------------------------------------------------
        let mut line_allows: Vec<AllowSpec> = Vec::new();
        let mut safety_here = false;
        for c in &line.comments {
            if c.contains("SAFETY:") {
                safety_here = true;
            }
            for m in parse_markers(c) {
                match m {
                    Marker::Hot => pending = Some(RegionKind::Hot),
                    Marker::Profiling => pending = Some(RegionKind::Profiling),
                    Marker::Allow(a) => line_allows.push(a),
                }
            }
        }

        let compact: String = line.code.chars().filter(|c| !c.is_whitespace()).collect();

        // -- #[cfg(test)] gates the next item ------------------------------
        if compact.contains("cfg(test)") || compact.contains("cfg(all(test") {
            pending = Some(RegionKind::CfgTest);
        }

        // -- pending annotation attaches to the next item keyword ----------
        if let Some(kind) = pending {
            let keyword = match kind {
                RegionKind::Hot | RegionKind::Profiling => count_ident(&line.code, "fn") > 0,
                RegionKind::CfgTest => {
                    count_ident(&line.code, "mod") > 0
                        || count_ident(&line.code, "fn") > 0
                        || count_ident(&line.code, "impl") > 0
                }
            };
            if keyword {
                awaiting = Some(kind);
                pending = None;
            }
        }

        // -- brace tracking ------------------------------------------------
        let mut hot = regions.iter().any(|r| r.kind == RegionKind::Hot);
        let mut profiling = regions.iter().any(|r| r.kind == RegionKind::Profiling);
        let mut in_test = regions.iter().any(|r| r.kind == RegionKind::CfgTest);
        for ch in line.code.chars() {
            match ch {
                '(' | '[' => paren += 1,
                ')' | ']' => paren -= 1,
                ';' if paren == 0 && awaiting.is_some() => {
                    // item ended without a body (e.g. a trait signature)
                    awaiting = None;
                }
                '{' => {
                    if let Some(kind) = awaiting.take() {
                        regions.push(Region {
                            kind,
                            open_depth: depth,
                        });
                        match kind {
                            RegionKind::Hot => hot = true,
                            RegionKind::Profiling => profiling = true,
                            RegionKind::CfgTest => in_test = true,
                        }
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    while regions.last().is_some_and(|r| depth <= r.open_depth) {
                        regions.pop();
                    }
                }
                _ => {}
            }
        }

        // -- rule checks ---------------------------------------------------
        if has_code && !in_test {
            let allows: Vec<&AllowSpec> = carried.iter().chain(line_allows.iter()).collect();
            let push = |rule: Rule, message: &str, findings: &mut Vec<Finding>| {
                let waived = allows
                    .iter()
                    .find(|a| a.applies(rule))
                    .map(|a| a.reason.clone());
                findings.push(Finding {
                    rule,
                    file: file.to_path_buf(),
                    line: lineno,
                    message: message.to_string(),
                    waived,
                });
            };

            if critical && cfg.enabled(Rule::D1) {
                for ident in ["HashMap", "HashSet"] {
                    for _ in 0..count_ident(&line.code, ident) {
                        push(
                            Rule::D1,
                            &format!("unordered `{ident}` in a determinism-critical module"),
                            &mut findings,
                        );
                    }
                }
                for _ in 0..count_ident(&line.code, "mpsc") {
                    push(
                        Rule::D1,
                        "`mpsc` in a determinism-critical module",
                        &mut findings,
                    );
                }
            }

            if cfg.enabled(Rule::D2) && !profiling {
                let hits = count_sub(&compact, "Instant::now", true)
                    + count_ident(&line.code, "SystemTime");
                for _ in 0..hits {
                    push(
                        Rule::D2,
                        "wall-clock read outside a profiling-annotated region",
                        &mut findings,
                    );
                }
            }

            if cfg.enabled(Rule::D3) && !approved_d3 {
                let sum_f32 = count_sub(&compact, "sum::<f32>", false);
                let mut folds = 0;
                for pat in ["fold(0.0f32", "fold(0f32", "fold(0.0_f32", "fold(0_f32"] {
                    folds += count_sub(&compact, pat, false);
                }
                let ascribed =
                    sum_f32 == 0 && compact.contains(":f32") && compact.contains(".sum()");
                for _ in 0..(sum_f32 + folds + usize::from(ascribed)) {
                    push(
                        Rule::D3,
                        "f32 reduction outside the approved fused kernels",
                        &mut findings,
                    );
                }
            }

            if cfg.enabled(Rule::H1) && hot {
                for (name, pat, bounded) in H1_PATTERNS {
                    for _ in 0..count_sub(&compact, pat, *bounded) {
                        push(
                            Rule::H1,
                            &format!("allocating construct `{name}` in a hot function"),
                            &mut findings,
                        );
                    }
                }
            }

            if cfg.enabled(Rule::U1) && !(safety_here || safety_above) {
                for _ in 0..count_ident(&line.code, "unsafe") {
                    push(
                        Rule::U1,
                        "`unsafe` without a `// SAFETY:` comment",
                        &mut findings,
                    );
                }
            }
        }

        // -- carry state to the next line ----------------------------------
        if has_code {
            carried.clear();
            safety_above = false;
        } else {
            carried.extend(line_allows);
            if safety_here {
                safety_above = true;
            }
        }
    }
    findings
}

/// Scan one file from disk.
pub fn scan_file(path: &Path, cfg: &Config) -> io::Result<Vec<Finding>> {
    let src = fs::read_to_string(path)?;
    Ok(scan_source(path, &src, cfg))
}

/// Recursively collect `.rs` files under `root` in sorted (deterministic)
/// order.
pub fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(root)?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for entry in entries {
        if entry.is_dir() {
            collect_rs_files(&entry, out)?;
        } else if entry.extension().is_some_and(|x| x == "rs") {
            out.push(entry);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under the given paths.
pub fn scan_paths(paths: &[PathBuf], cfg: &Config) -> io::Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs_files(p, &mut files)?;
    }
    let mut report = Report {
        files_scanned: files.len(),
        ..Report::default()
    };
    for f in &files {
        report.findings.extend(scan_file(f, cfg)?);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(path: &str, src: &str) -> Vec<Finding> {
        scan_source(Path::new(path), src, &Config::default())
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = r#"
fn f() {
    let s = "HashMap Instant::now unsafe";
    // HashMap in a comment
    /* Instant::now in a block
       comment spanning lines */
}
"#;
        assert!(scan("rust/src/net/x.rs", src).is_empty());
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char {\n    let c = '\\'';\n    let d = 'x';\n    c\n}\n";
        assert!(scan("rust/src/net/x.rs", src).is_empty());
    }

    #[test]
    fn d1_only_fires_in_critical_modules() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(scan("rust/src/coordinator/x.rs", src).len(), 1);
        assert_eq!(scan("rust/src/util/x.rs", src).len(), 0);
    }

    #[test]
    fn allow_waives_same_line_and_next_line() {
        let trailing =
            "use std::collections::HashMap; // detlint: allow(D1) — sorted before drain\n";
        let f = scan("rust/src/net/x.rs", trailing);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].waived.as_deref(), Some("sorted before drain"));

        let above = "// detlint: allow(D1) — reason\nuse std::collections::HashMap;\n";
        let f = scan("rust/src/net/x.rs", above);
        assert_eq!(f.len(), 1);
        assert!(f[0].waived.is_some());

        // the allow does not leak past the next code line
        let leak = "// detlint: allow(D1) — reason\nfn g() {}\nuse std::collections::HashMap;\n";
        let f = scan("rust/src/net/x.rs", leak);
        assert_eq!(f.len(), 1);
        assert!(f[0].waived.is_none());
    }

    #[test]
    fn hot_region_ends_at_the_function_brace() {
        let src = "// detlint: hot\nfn hot() {\n    let v = Vec::new();\n}\n\
                   fn cold() {\n    let v = Vec::new();\n}\n";
        let f = scan("rust/src/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
        assert_eq!(f[0].rule, Rule::H1);
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    \
                   fn t() { let _ = std::time::Instant::now(); }\n}\n";
        assert!(scan("rust/src/net/x.rs", src).is_empty());
    }

    #[test]
    fn u1_requires_safety_comment() {
        let bad = "fn f() {\n    unsafe { core::hint::unreachable_unchecked() }\n}\n";
        let f = scan("rust/src/x.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::U1);

        let good = "fn f() {\n    // SAFETY: provably unreachable\n    \
                    unsafe { core::hint::unreachable_unchecked() }\n}\n";
        assert!(scan("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn d3_spares_the_approved_kernels() {
        let src = "fn s(x: &[f32]) -> f32 { x.iter().sum::<f32>() }\n";
        assert_eq!(scan("rust/src/compress/wire.rs", src).len(), 0);
        assert_eq!(scan("rust/src/model/x.rs", src).len(), 1);
    }

    #[test]
    fn report_json_is_well_formed_enough() {
        let report = Report {
            findings: scan("rust/src/net/x.rs", "use std::collections::HashMap;\n"),
            files_scanned: 1,
        };
        let json = report.to_json();
        assert!(json.contains("\"rule\": \"D1\""));
        assert!(json.contains("\"unwaived\": 1"));
    }
}
