//! Fixture-driven integration tests for detlint.
//!
//! Each fixture under `tests/fixtures/` is self-describing: a line that
//! must produce an unwaived finding carries an `[EXPECT:RULE]` marker in a
//! trailing comment, a line that must produce a waived finding carries
//! `[EXPECT-WAIVED:RULE]`. Every other line must scan clean, so the full
//! multiset comparison below checks exact finding counts *and* locations,
//! and every unmarked line doubles as a negative case.
//!
//! The CLI-level tests exercise the exit-code contract: `--deny-all` over
//! the fixture tree (which deliberately seeds violations of all five
//! rules) must fail, the clean fixture directory must pass, and — the
//! acceptance criterion for this tool — the real `rust/src` tree must
//! pass with `--deny-all`.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::Command;

use detlint::{collect_rs_files, scan_file, Config, Rule};

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

/// `(line, rule, waived)` triples, sorted.
type Triples = Vec<(usize, String, bool)>;

fn expected_for(src: &str) -> Triples {
    let mut out = Vec::new();
    for (idx, line) in src.lines().enumerate() {
        let mut rest = line;
        while let Some(pos) = rest.find("[EXPECT") {
            let tail = &rest[pos..];
            let close = tail.find(']').expect("unclosed [EXPECT marker");
            let marker = &tail[..close];
            let (waived, rule) = if let Some(r) = marker.strip_prefix("[EXPECT-WAIVED:") {
                (true, r)
            } else if let Some(r) = marker.strip_prefix("[EXPECT:") {
                (false, r)
            } else {
                panic!("malformed marker {marker:?}");
            };
            assert!(
                Rule::parse(rule).is_some(),
                "marker names unknown rule {rule:?}"
            );
            out.push((idx + 1, rule.to_string(), waived));
            rest = &tail[close..];
        }
    }
    out.sort();
    out
}

fn actual_for(path: &Path) -> Triples {
    let mut out: Triples = scan_file(path, &Config::default())
        .expect("scan fixture")
        .into_iter()
        .map(|f| (f.line, f.rule.name().to_string(), f.waived.is_some()))
        .collect();
    out.sort();
    out
}

#[test]
fn every_fixture_matches_its_expect_markers_exactly() {
    let mut files = Vec::new();
    collect_rs_files(&fixtures_dir(), &mut files).expect("walk fixtures");
    assert!(files.len() >= 9, "fixture tree went missing: {files:?}");

    let mut positives_by_rule: Vec<String> = Vec::new();
    let mut waived_by_rule: Vec<String> = Vec::new();
    for file in &files {
        let src = fs::read_to_string(file).expect("read fixture");
        let expected = expected_for(&src);
        let actual = actual_for(file);
        assert_eq!(
            expected,
            actual,
            "findings mismatch in {} (left = expected from markers, right = scanner)",
            file.display()
        );
        for (_, rule, waived) in expected {
            if waived {
                waived_by_rule.push(rule);
            } else {
                positives_by_rule.push(rule);
            }
        }
    }
    // Acceptance: all five rule families have a fixture-verified positive
    // and a fixture-verified waived case (negatives are every unmarked
    // line, checked by the exact-match assertion above).
    for rule in Rule::ALL {
        assert!(
            positives_by_rule.iter().any(|r| r == rule.name()),
            "no positive fixture case for {rule}"
        );
        assert!(
            waived_by_rule.iter().any(|r| r == rule.name()),
            "no allow-waived fixture case for {rule}"
        );
    }
}

#[test]
fn clean_fixture_dir_has_no_findings() {
    let mut files = Vec::new();
    collect_rs_files(&fixtures_dir().join("clean"), &mut files).expect("walk clean");
    for file in &files {
        let findings = scan_file(file, &Config::default()).expect("scan clean");
        assert!(findings.is_empty(), "unexpected findings: {findings:?}");
    }
}

fn run_detlint(args: &[&str]) -> std::process::Output {
    let exe = env!("CARGO_BIN_EXE_detlint");
    Command::new(exe).args(args).output().expect("run detlint")
}

#[test]
fn deny_all_fails_on_seeded_violations() {
    let dir = fixtures_dir();
    let out = run_detlint(&[dir.to_str().unwrap(), "--deny-all", "--quiet"]);
    assert_eq!(
        out.status.code(),
        Some(1),
        "seeded fixture violations must fail --deny-all"
    );
}

#[test]
fn without_deny_all_findings_do_not_fail() {
    let dir = fixtures_dir();
    let out = run_detlint(&[dir.to_str().unwrap(), "--quiet"]);
    assert_eq!(out.status.code(), Some(0));
}

#[test]
fn deny_all_passes_on_clean_fixtures() {
    let dir = fixtures_dir().join("clean");
    let out = run_detlint(&[dir.to_str().unwrap(), "--deny-all"]);
    assert_eq!(out.status.code(), Some(0));
}

#[test]
fn json_report_has_the_expected_shape() {
    let dir = fixtures_dir();
    let out = run_detlint(&[dir.to_str().unwrap(), "--json"]);
    let stdout = String::from_utf8(out.stdout).expect("utf8 json");
    for key in [
        "\"tool\": \"detlint\"",
        "\"files_scanned\"",
        "\"unwaived\"",
        "\"counts\"",
        "\"findings\"",
        "\"rule\": \"D1\"",
        "\"waive_reason\"",
    ] {
        assert!(stdout.contains(key), "missing {key} in JSON:\n{stdout}");
    }
}

#[test]
fn unknown_flag_is_a_usage_error() {
    let out = run_detlint(&["--definitely-not-a-flag"]);
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn real_tree_is_clean_under_deny_all() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let out = run_detlint(&[root.to_str().unwrap(), "--deny-all"]);
    assert_eq!(
        out.status.code(),
        Some(0),
        "rust/src must be detlint-clean; output:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
}
