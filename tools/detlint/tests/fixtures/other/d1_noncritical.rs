//! D1 negative: `HashMap` outside the determinism-critical modules is
//! allowed — D1 is scoped by path, not global. This whole file must scan
//! clean.

use std::collections::HashMap;

pub fn histogram(xs: &[u32]) -> HashMap<u32, u32> {
    let mut m = HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m
}
