//! D3 negative: this fixture's file name (`wire.rs`) is on the approved
//! fused-kernel list, so f32 reductions here are exempt by policy. The
//! file sits under `compress/` (critical), so D1 still applies — and the
//! BTreeMap below shows the sanctioned collection scanning clean.

use std::collections::BTreeMap;

pub fn fused_reduce(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}

pub fn lane_table() -> BTreeMap<u8, f32> {
    BTreeMap::new()
}
