//! `#[cfg(test)]` suppression fixture: everything inside the test module
//! would trip D1 and D2 but must be skipped; the top-level import is the
//! one real finding in this file.

use std::collections::HashMap; // [EXPECT:D1]

pub fn touch(m: &HashMap<u32, u32>) -> usize { // [EXPECT:D1]
    m.len()
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use std::time::Instant;

    #[test]
    fn wall_clock_and_hashmap_are_fine_in_tests() {
        let t0 = Instant::now();
        let mut m = HashMap::new();
        m.insert(1u32, t0.elapsed().as_nanos() as u64);
        assert_eq!(m.len(), 1);
    }
}
