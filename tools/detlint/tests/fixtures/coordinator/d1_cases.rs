//! D1 fixtures: unordered collections / mpsc inside a determinism-critical
//! module ("coordinator" is in the default critical set). Tagged lines
//! must produce exactly one D1 finding, unwaived or waived per the marker.
//! (Spelling a marker out in this header would register as an expectation
//! on the header line itself.)

use std::collections::BTreeMap;
use std::collections::HashMap; // [EXPECT:D1]
use std::collections::HashSet; // [EXPECT:D1]
use std::sync::mpsc; // [EXPECT:D1]

pub fn ordered_table() -> BTreeMap<u32, f64> {
    BTreeMap::new()
}

pub fn bad_cache() -> usize {
    let m = HashMap::new(); // [EXPECT:D1]
    let _ = m.insert(1u32, 2u32);
    m.len()
}

pub fn sanctioned_cache() -> usize {
    // detlint: allow(D1) — keys are drained through a sorted Vec before use
    let m = std::collections::HashMap::<u32, u32>::new(); // [EXPECT-WAIVED:D1]
    m.len()
}
