//! H1 fixtures: allocating constructs inside a hot-annotated function.
//! The same constructs in the un-annotated `cold_path` are negatives; the
//! pool-growth `resize_with` shows the line-level waiver form. (The
//! annotation name is spelled out only at its real use sites below.)

// detlint: hot
pub fn hot_path(buf: &mut Vec<u8>, src: &[u8]) -> usize {
    let v: Vec<u8> = Vec::new(); // [EXPECT:H1]
    let w = vec![0u8; 4]; // [EXPECT:H1]
    let x = src.to_vec(); // [EXPECT:H1]
    let y: Vec<u8> = src.iter().copied().collect(); // [EXPECT:H1]
    let msg = format!("{}", src.len()); // [EXPECT:H1]
    let z = x.clone(); // [EXPECT:H1]
    buf.len() + v.len() + w.len() + y.len() + msg.len() + z.len()
}

pub fn cold_path(src: &[u8]) -> Vec<u8> {
    let mut out = src.to_vec();
    out.push(0);
    out
}

// detlint: hot
pub fn hot_waived(partials: &mut Vec<Vec<u8>>, n: usize) -> usize {
    // detlint: allow(H1) — resize_with only fills on pool growth, not per round
    partials.resize_with(n, Vec::new); // [EXPECT-WAIVED:H1]
    partials.len()
}
