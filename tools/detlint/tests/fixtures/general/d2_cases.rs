//! D2 fixtures: wall-clock reads. Positive outside any region, negative
//! inside a profiling-annotated function, positive again after the region
//! closes, waived via a trailing allow. (The annotation name is spelled
//! out only at its real use sites below — writing it in this header would
//! itself annotate the first function.)

use std::time::{Duration, Instant};

pub fn naked_now() -> Instant {
    Instant::now() // [EXPECT:D2]
}

// detlint: profiling
pub fn timed_section() -> Duration {
    let t0 = Instant::now();
    t0.elapsed()
}

pub fn after_region() -> bool {
    let now = std::time::SystemTime::now(); // [EXPECT:D2]
    now.elapsed().is_ok()
}

pub fn stamped() -> Instant {
    Instant::now() // [EXPECT-WAIVED:D2] detlint: allow(D2) — wall-clock log stamp by design
}
