//! D3 fixtures: f32 reduction idioms outside the approved fused kernels.
//! `sum::<f32>`, an f32 `fold`, and an ascribed `: f32` + `.sum()` binding
//! are positives; the f64 reduction is the sanctioned alternative.

pub fn turbofish(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>() // [EXPECT:D3]
}

pub fn folded(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, b| a + b) // [EXPECT:D3]
}

pub fn ascribed(xs: &[f32]) -> f32 {
    let total: f32 = xs.iter().copied().sum(); // [EXPECT:D3]
    total
}

pub fn double_precision(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}

pub fn sanctioned(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>() // [EXPECT-WAIVED:D3] detlint: allow(D3) — fixed-order local reduction
}
