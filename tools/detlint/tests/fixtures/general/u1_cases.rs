//! U1 fixtures: `unsafe` must carry a SAFETY comment on the same line or
//! in the comment block directly above it. (The literal marker text is
//! spelled out only at its real use sites below — in this header it would
//! leak coverage onto the first code line.)

static mut COUNTER: u64 = 0;

pub fn bare_block() {
    unsafe { // [EXPECT:U1]
        COUNTER += 1;
    }
}

pub fn documented_block() {
    // SAFETY: fixture is single-threaded; no aliasing of COUNTER.
    unsafe {
        COUNTER += 1;
    }
}

pub fn inline_documented() -> u64 {
    unsafe { COUNTER } // SAFETY: read-only access, single-threaded fixture
}

pub unsafe fn bare_fn() {} // [EXPECT:U1]

// detlint: allow(U1) — contract documented on the trait, not repeated here
pub unsafe fn waived_fn() {} // [EXPECT-WAIVED:U1]
