//! A fully clean fixture: no findings under any rule. Used by the CLI
//! exit-code test (`--deny-all` on this directory must exit 0).

use std::collections::BTreeMap;

pub fn deterministic_sum(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}

pub fn table() -> BTreeMap<u32, &'static str> {
    let mut m = BTreeMap::new();
    m.insert(1, "one");
    m.insert(2, "two");
    m
}
