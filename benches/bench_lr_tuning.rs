//! Regenerates the paper artifact: Table 2 LR grid (`repro exp table2`).
//! Full sizes with BENCH_FULL=1; quick otherwise.
use ef_sgd::bench::Bench;
use ef_sgd::experiments::{self, ExpContext};

fn main() {
    let ctx = ExpContext {
        quick: std::env::var("BENCH_FULL").map_or(true, |v| v != "1"),
        out_dir: "results".into(),
        ..Default::default()
    };
    let mut b = Bench::with_config(
        "Table 2 LR grid",
        ef_sgd::bench::BenchConfig {
            measure_time: std::time::Duration::from_millis(1),
            warmup_time: std::time::Duration::from_millis(0),
            samples: 1,
        },
    );
    b.bench("table2", || {
        experiments::run("table2", &ctx).expect("table2");
    });
    b.finish();
}
