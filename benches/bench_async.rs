//! Async engine benchmark: bounded-staleness rounds under lognormal
//! stragglers at n ∈ {4, 16} workers, full vs half quorum, measuring the
//! host-side throughput of the discrete-event loop (rounds/sec), the
//! virtual-clock time per round, and how much staleness the schedule
//! actually produced. Emits `results/BENCH_async.json` so the async
//! engine's perf trajectory is tracked from this PR onward.

use ef_sgd::bench::{quick_mode, Bench};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::async_driver::AsyncTrainDriver;
use ef_sgd::coordinator::driver::DriverConfig;
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::{StragglerModel, StragglerSchedule};
use ef_sgd::util::Pcg64;

fn make_driver(n: usize, d: usize, quorum: usize, staleness: u64, threads: usize) -> AsyncTrainDriver {
    let workers: Vec<Worker> = (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::seeded(100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                64,
                4,
                Pcg64::seeded(id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps: usize::MAX, // rounds are driven manually below
        schedule: LrSchedule::constant(0.01),
        straggler: StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma: 1.0 }, 7),
        threads,
        ..Default::default()
    };
    AsyncTrainDriver::new(cfg, quorum, staleness, workers, vec![0.5f32; d])
}

struct Row {
    workers: usize,
    quorum: usize,
    staleness_bound: u64,
    d: usize,
    rounds_per_sec: f64,
    sim_ms_per_round: f64,
    stale_frac: f64,
    mean_batch: f64,
}

fn main() {
    let d = if quick_mode() { 16_384 } else { 262_144 };
    let mut b = Bench::new(&format!("async bounded-staleness engine (d = {d})"));
    let mut rows: Vec<Row> = Vec::new();

    for &(n, quorum, bound, threads) in
        &[(4usize, 4usize, 0u64, 4usize), (4, 2, 2, 4), (16, 8, 3, 8)]
    {
        let mut driver = make_driver(n, d, quorum, bound, threads);
        let mut rec = Recorder::new();
        let name = format!("fold n={n} K={quorum} S={bound}");
        let res = b.bench_elems(&name, n as u64, || {
            driver.step_round(&mut rec);
        });
        let rounds = driver.rounds();
        rows.push(Row {
            workers: n,
            quorum,
            staleness_bound: bound,
            d,
            rounds_per_sec: 1.0 / res.mean.as_secs_f64(),
            sim_ms_per_round: driver.sim_time_s() * 1e3 / rounds as f64,
            stale_frac: driver.staleness().stale_fraction(),
            mean_batch: driver.staleness().mean_batch(),
        });
    }
    b.finish();

    // hand-rolled JSON (no serde offline); one object per config row
    let mut json = String::from("{\n  \"bench\": \"async_engine\",\n");
    json.push_str(&format!("  \"quick\": {},\n  \"configs\": [\n", quick_mode()));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {}, \"quorum\": {}, \"max_staleness\": {}, \"d\": {}, \
             \"rounds_per_sec\": {:.3}, \"sim_ms_per_round\": {:.4}, \
             \"stale_frac\": {:.4}, \"mean_batch\": {:.2}}}{}\n",
            r.workers,
            r.quorum,
            r.staleness_bound,
            r.d,
            r.rounds_per_sec,
            r.sim_ms_per_round,
            r.stale_frac,
            r.mean_batch,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::create_dir_all("results").expect("create results dir");
    let path = "results/BENCH_async.json";
    std::fs::write(path, &json).expect("write BENCH_async.json");
    println!("wrote {path}");
}
