//! Fabric-layer benchmark for the zero-copy / allocation-free steady
//! state: one "fabric round" = dense parameter broadcast to n workers →
//! per-worker receive → scaled-sign encode + push → leader gather + fused
//! decode. Two implementations of the identical traffic are measured:
//!
//! * **pooled** — the engine's hot path: `make_broadcast` refreshes the
//!   Arc-shared slices in place (one copy of θ per round, refcount bumps
//!   per recipient), workers encode into recycled `FramePool` buffers,
//!   and the leader's gather/decode reuses persistent scratch. Steady
//!   state allocates nothing (asserted here with the counting allocator).
//! * **legacy** — a faithful emulation of the pre-zero-copy engine: the
//!   leader clones the dense parameter vector once per worker
//!   (`Arc::from(&theta[..])` ≙ the old `params.to_vec()`), workers build
//!   fresh encode buffers each step, and the leader's gather and
//!   accumulators are freshly allocated per round.
//!
//! The acceptance bar from the PR issue: pooled ≥ 2x legacy rounds/sec on
//! the dense-broadcast n = 16 configuration, and pooled allocs/round = 0.
//! A full-engine row (TrainDriver, n = 16, threads = 4) is included for
//! context, along with a sign decode+accumulate kernel row that times the
//! vectorized word-unpack against its per-bit scalar reference (bitwise
//! parity asserted; CI requires ≥ 2x). Emits `results/BENCH_fabric.json`.

use ef_sgd::bench::quick_mode;
use ef_sgd::collectives::{ShardPlan, ShardedParameterServer};
use ef_sgd::compress::wire::{self, Encoded};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::{Fabric, LinkModel, Message, MessageKind, Payload};
use ef_sgd::util::alloc_count::{self, CountingAllocator};
use ef_sgd::util::Pcg64;
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Persistent state of the pooled (engine hot path) fabric round.
struct PooledState {
    bcast: Vec<Arc<[f32]>>,
    worker_bufs: Vec<Vec<f32>>,
    frames: Vec<Encoded>,
    msgs: Vec<(Message, f64)>,
    gathered: Vec<Encoded>,
    acc: Vec<f32>,
}

fn pooled_round(
    fabric: &Fabric,
    ps: &ShardedParameterServer,
    theta: &[f32],
    round: u64,
    st: &mut PooledState,
) {
    ps.make_broadcast(theta, &mut st.bcast);
    ps.broadcast_shared(fabric, round, &st.bcast);
    for (w, buf) in st.worker_bufs.iter_mut().enumerate() {
        assert!(ps.recv_params_into(fabric, w, buf));
        let mut enc = Encoded::recycled(fabric.frame_pool().take());
        wire::encode_scaled_sign_into(buf, &mut enc);
        st.frames.push(enc);
        ps.push_frames(fabric, w, round, &mut st.frames);
    }
    let _latest = ps
        .gather_shard_into(fabric, round, 0, &mut st.msgs, &mut st.gathered)
        .expect("gather");
    st.acc.fill(0.0);
    for e in st.gathered.drain(..) {
        wire::decode_any_add(&e, &mut st.acc).expect("decode");
        fabric.frame_pool().put(e.bytes);
    }
}

/// The pre-PR engine's allocation pattern on the identical traffic.
fn legacy_round(fabric: &Fabric, ps: &ShardedParameterServer, theta: &[f32], round: u64) {
    let leader = ps.leaders[0];
    for &w in &ps.workers {
        // the historical per-worker dense clone (params.to_vec())
        fabric.send(Message {
            src: leader,
            dst: w,
            round,
            kind: MessageKind::ParamBroadcast,
            payload: Payload::Params(Arc::from(theta)),
        });
    }
    for &w in &ps.workers {
        let msg = fabric.recv(w).expect("broadcast missing");
        let params = match msg.payload {
            Payload::Params(p) => p,
            other => panic!("unexpected payload {other:?}"),
        };
        // fresh encode buffer every step (the pre-into encoders)
        let enc = wire::encode_scaled_sign(&params);
        fabric.send(Message {
            src: w,
            dst: leader,
            round,
            kind: MessageKind::GradPush,
            payload: Payload::Grad(enc),
        });
    }
    // freshly allocated gather + accumulator every round
    let mut msgs = fabric.recv_all_timed(leader);
    msgs.sort_by_key(|(m, _)| m.src);
    let mut acc = vec![0.0f32; theta.len()];
    for (msg, _arrival) in msgs {
        if let Payload::Grad(e) = msg.payload {
            wire::decode_any_add(&e, &mut acc).expect("decode");
        }
    }
}

struct Row {
    path: &'static str,
    rounds_per_sec: f64,
    allocs_per_round: f64,
    copied_bytes_per_round: u64,
}

/// Per-bit scalar reference for the sign decode kernel (the same contract
/// as the `#[cfg(test)]` parity reference in `compress::wire`): one
/// bounds-checked bit read and one branchy ±scale select per coordinate.
fn scalar_sign_decode_add(e: &Encoded, acc: &mut [f32]) {
    let b = &e.bytes;
    let scale = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    let body = &b[4..];
    let mut pos = 0u64;
    for a in acc.iter_mut() {
        let idx = (pos / 8) as usize;
        assert!(idx < body.len(), "sign bit out of range");
        let bit = (body[idx] >> (pos % 8)) & 1 == 1;
        pos += 1;
        *a += if bit { scale } else { -scale };
    }
}

/// Vectorized-vs-scalar speedup of the fused sign decode+accumulate (the
/// per-frame leader kernel the pooled gather runs): asserts bitwise parity
/// first, then times both paths. Returns (Mcoord/s vectorized, speedup).
fn bench_sign_kernel(d: usize) -> (f64, f64) {
    let reps = if quick_mode() { 400u32 } else { 60 };
    let mut rng = Pcg64::seeded(42);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 0.0, 1.0);
    let frame = wire::encode_scaled_sign(&v);

    let mut fast = vec![0.25f32; d];
    let mut slow = fast.clone();
    wire::decode_scaled_sign_add(&frame, &mut fast).expect("decode");
    scalar_sign_decode_add(&frame, &mut slow);
    assert!(
        fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()),
        "sign decode parity"
    );

    let mut acc = vec![0.0f32; d];
    let time = |f: &mut dyn FnMut()| {
        f();
        let t = std::time::Instant::now();
        for _ in 0..reps {
            f();
        }
        t.elapsed().as_secs_f64() / f64::from(reps)
    };
    let t_vec = time(&mut || {
        wire::decode_scaled_sign_add(std::hint::black_box(&frame), &mut acc).expect("decode");
    });
    let t_scalar = time(&mut || {
        scalar_sign_decode_add(std::hint::black_box(&frame), &mut acc);
    });
    std::hint::black_box(&acc);
    (d as f64 / t_vec / 1e6, t_scalar / t_vec)
}

fn measure<F: FnMut(u64)>(rounds: u64, mut f: F) -> (f64, f64) {
    // warm-up sizes every pool and cache
    for r in 0..3 {
        f(r);
    }
    let alloc_before = alloc_count::allocs();
    let t = std::time::Instant::now();
    for r in 3..3 + rounds {
        f(r);
    }
    let wall = t.elapsed().as_secs_f64();
    let allocs = (alloc_count::allocs() - alloc_before) as f64 / rounds as f64;
    (rounds as f64 / wall, allocs)
}

fn make_driver(n: usize, d: usize, threads: usize) -> TrainDriver {
    let workers: Vec<Worker> = (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::seeded(100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                64,
                4,
                Pcg64::seeded(id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps: 0,
        schedule: LrSchedule::constant(0.01),
        threads,
        ..Default::default()
    };
    TrainDriver::new(cfg, workers, vec![0.5f32; d])
}

fn main() {
    let d = if quick_mode() { 65_536 } else { 262_144 };
    let n = 16usize;
    let rounds = if quick_mode() { 20u64 } else { 100 };
    println!("\n== bench group: zero-copy fabric (dense broadcast, n = {n}, d = {d}) ==");

    let mut rng = Pcg64::seeded(7);
    let mut theta = vec![0.0f32; d];
    rng.fill_normal(&mut theta, 0.0, 1.0);

    // ---- pooled: the engine hot path --------------------------------
    let plan = ShardPlan::single(d);
    let fabric = Fabric::new(n + 1, LinkModel::default());
    let ps = ShardedParameterServer::new(&fabric, plan.clone());
    let mut st = PooledState {
        bcast: Vec::new(),
        worker_bufs: (0..n).map(|_| Vec::new()).collect(),
        frames: Vec::new(),
        msgs: Vec::new(),
        gathered: Vec::new(),
        acc: vec![0.0f32; d],
    };
    let (pooled_rps, pooled_allocs) =
        measure(rounds, |r| pooled_round(&fabric, &ps, &theta, r, &mut st));

    // ---- legacy: the pre-PR allocation pattern ----------------------
    let fabric2 = Fabric::new(n + 1, LinkModel::default());
    let ps2 = ShardedParameterServer::new(&fabric2, plan);
    let (legacy_rps, legacy_allocs) =
        measure(rounds, |r| legacy_round(&fabric2, &ps2, &theta, r));

    // host-memory copy accounting (bytes of f32 traffic actually copied
    // per round, excluding the identical decode reads on both paths):
    // pooled = one θ refresh + n worker receive copies;
    // legacy = n broadcast clones (the n receives then move, not copy).
    let pooled_copied = (d * 4 * (1 + n)) as u64;
    let legacy_copied = (d * 4 * n) as u64;

    let speedup = pooled_rps / legacy_rps;
    let mut rows = vec![
        Row {
            path: "pooled",
            rounds_per_sec: pooled_rps,
            allocs_per_round: pooled_allocs,
            copied_bytes_per_round: pooled_copied,
        },
        Row {
            path: "legacy",
            rounds_per_sec: legacy_rps,
            allocs_per_round: legacy_allocs,
            copied_bytes_per_round: legacy_copied,
        },
    ];
    for r in &rows {
        println!(
            "  {:<8} rounds/s {:>10.2}  allocs/round {:>8.1}  copied {:>12} B/round",
            r.path, r.rounds_per_sec, r.allocs_per_round, r.copied_bytes_per_round
        );
    }
    println!("  speedup pooled vs legacy: {speedup:.2}x (acceptance bar: >= 2x)");
    println!(
        "  pooled steady-state allocs/round: {pooled_allocs:.1} (acceptance bar: 0)"
    );

    // ---- sign decode+accumulate kernel row --------------------------
    let (sign_mcoords, sign_speedup) = bench_sign_kernel(d);
    println!(
        "  sign decode kernel: {sign_mcoords:.1} Mcoord/s, {sign_speedup:.2}x vs per-bit scalar \
         (acceptance bar: >= 2x)"
    );

    // ---- full engine context row ------------------------------------
    let mut driver = make_driver(n, d, 4);
    let mut rec = Recorder::new();
    let engine_rounds = if quick_mode() { 6u64 } else { 20 };
    driver.round(&mut rec); // warm
    driver.round(&mut rec);
    rec.reserve_all(engine_rounds as usize + 4);
    let alloc_before = alloc_count::allocs();
    let t = std::time::Instant::now();
    for _ in 0..engine_rounds {
        driver.round(&mut rec);
    }
    let engine_wall = t.elapsed().as_secs_f64();
    let engine_allocs = (alloc_count::allocs() - alloc_before) as f64 / engine_rounds as f64;
    let engine_rps = engine_rounds as f64 / engine_wall;
    println!(
        "  engine   rounds/s {engine_rps:>10.2}  allocs/round {engine_allocs:>8.1}  (TrainDriver n={n} threads=4 scaled-sign)"
    );
    println!("== end group ==");
    rows.push(Row {
        path: "engine",
        rounds_per_sec: engine_rps,
        allocs_per_round: engine_allocs,
        copied_bytes_per_round: pooled_copied,
    });

    // hand-rolled JSON (no serde offline)
    let mut json = String::from("{\n  \"bench\": \"fabric_zero_copy\",\n");
    json.push_str(&format!(
        "  \"quick\": {},\n  \"workers\": {n},\n  \"d\": {d},\n  \
         \"speedup_pooled_vs_legacy\": {speedup:.3},\n  \
         \"sign_decode_mcoords_per_sec\": {sign_mcoords:.1},\n  \
         \"sign_decode_speedup_vs_scalar\": {sign_speedup:.3},\n  \"configs\": [\n",
        quick_mode()
    ));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"path\": \"{}\", \"rounds_per_sec\": {:.3}, \"allocs_per_round\": {:.2}, \
             \"copied_bytes_per_round\": {}}}{}\n",
            r.path,
            r.rounds_per_sec,
            r.allocs_per_round,
            r.copied_bytes_per_round,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::create_dir_all("results").expect("create results dir");
    let path = "results/BENCH_fabric.json";
    std::fs::write(path, &json).expect("write BENCH_fabric.json");
    println!("wrote {path}");
}
