//! End-to-end round rate of the full coordinator stack: native-MLP workers
//! (always) and the PJRT transformer workers (when artifacts are built).

use ef_sgd::bench::{Bench, BenchConfig};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver, UpdateRule};
use ef_sgd::coordinator::worker::{GradSource, ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::data::synth_class::{self, SynthSpec};
use ef_sgd::data::tokens::MarkovCorpus;
use ef_sgd::model::mlp::{Mlp, MlpObjective};
use ef_sgd::runtime::{LmSession, Runtime};
use ef_sgd::util::Pcg64;
use std::sync::Arc;
use std::time::Duration;

struct LmWorkerSource {
    session: Arc<LmSession>,
    corpus: Arc<MarkovCorpus>,
    rng: Pcg64,
}

impl GradSource for LmWorkerSource {
    fn dim(&self) -> usize {
        self.session.d()
    }

    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64 {
        let (b, s) = self.session.model.token_shape();
        let tokens = self.corpus.sample_batch(b, s, &mut self.rng);
        let (loss, grad) = self.session.train_step(theta, &tokens).expect("lm step");
        out.copy_from_slice(&grad);
        loss
    }
}

fn mlp_rounds_per_run(n_workers: usize, rounds: usize, threads: usize) {
    let spec = SynthSpec::cifar100_like();
    let mut rng = Pcg64::seeded(0);
    let (train, _) = synth_class::generate(&spec, &mut rng);
    let mlp = Mlp::new(ef_sgd::experiments::lr_tuning::mlp_config(&spec));
    let theta0 = mlp.init_params(&mut Pcg64::seeded(1));
    let workers: Vec<Worker> = (0..n_workers)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    MlpObjective::new(mlp.clone(), train.clone(), 32),
                    Pcg64::new(2, id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                64,
                4,
                Pcg64::new(3, id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps: rounds,
        schedule: LrSchedule::constant(0.02),
        update_rule: UpdateRule::ApplyAggregate,
        threads,
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers, theta0).run();
    std::hint::black_box(out.theta);
}

fn main() {
    let cfg = BenchConfig {
        measure_time: Duration::from_secs(2),
        warmup_time: Duration::from_millis(100),
        samples: 5,
    };
    let mut b = Bench::with_config("end-to-end coordinator rounds", cfg);
    for n in [1usize, 4, 8] {
        let rounds = 10;
        b.bench_elems(&format!("mlp ef-sign, {n} workers x {rounds} rounds"), rounds as u64, || {
            mlp_rounds_per_run(n, rounds, 1);
        });
    }
    // worker-pool scaling: same workload, more coordinator threads
    // (results are bit-identical; only wall-clock changes)
    for threads in [2usize, 4, 8] {
        let n = 8;
        let rounds = 10;
        b.bench_elems(
            &format!("mlp ef-sign, {n} workers x {rounds} rounds, {threads} threads"),
            rounds as u64,
            || {
                mlp_rounds_per_run(n, rounds, threads);
            },
        );
    }

    if let Ok(rt) = Runtime::load_default() {
        for model in ["tiny", "small"] {
            if rt.model(model).is_err() {
                continue;
            }
            let session = Arc::new(LmSession::open(&rt, model).expect("open"));
            let theta0 = rt.init_params(&session.model).unwrap();
            let corpus = Arc::new(MarkovCorpus::new(session.model.vocab, 3, 0));
            let rounds = 3usize;
            let s2 = session.clone();
            let c2 = corpus.clone();
            b.bench_elems(
                &format!("{model} transformer ef-sign, 2 workers x {rounds} rounds"),
                rounds as u64,
                move || {
                    let workers: Vec<Worker> = (0..2)
                        .map(|id| {
                            Worker::new(
                                id,
                                Box::new(LmWorkerSource {
                                    session: s2.clone(),
                                    corpus: c2.clone(),
                                    rng: Pcg64::new(4, id as u64),
                                }),
                                WorkerMode::ErrorFeedback,
                                CompressorKind::ScaledSign,
                                64,
                                4,
                                Pcg64::new(5, id as u64),
                            )
                        })
                        .collect();
                    let cfg = DriverConfig {
                        steps: rounds,
                        schedule: LrSchedule::constant(0.1),
                        ..Default::default()
                    };
                    let out = TrainDriver::new(cfg, workers, theta0.clone()).run();
                    std::hint::black_box(out.rounds);
                },
            );
        }
    } else {
        println!("(artifacts missing: transformer e2e cases skipped)");
    }
    b.finish();
}
