//! Sharded parameter-server benchmark: the leader's decode+aggregate
//! critical path (the slowest shard leader per round, from
//! `LeaderProfile`) and whole-round throughput as the shard count grows,
//! at n = 16 workers with Elias-packed QSGD frames. Emits
//! `results/BENCH_shard.json`; the acceptance bar is the S=4 critical
//! path landing ≥ 2x below S=1 (the per-shard decode work is ~d/S).

use ef_sgd::bench::quick_mode;
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::MessageKind;
use ef_sgd::util::Pcg64;

fn make_driver(n: usize, d: usize, shards: usize, threads: usize) -> TrainDriver {
    let workers: Vec<Worker> = (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::seeded(100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::Qsgd,
                64,
                4,
                Pcg64::seeded(id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps: 0, // rounds are driven manually below
        schedule: LrSchedule::constant(0.01),
        threads,
        shards,
        ..Default::default()
    };
    TrainDriver::new(cfg, workers, vec![0.5f32; d])
}

struct Row {
    shards: usize,
    rounds_per_sec: f64,
    leader_crit_ms: f64,
    leader_total_ms: f64,
    push_bytes_per_round: f64,
}

fn main() {
    let d = if quick_mode() { 32_768 } else { 262_144 };
    let n = 16;
    let threads = 4;
    let rounds = if quick_mode() { 6 } else { 20 };
    println!("\n== bench group: sharded PS leader critical path (d = {d}, n = {n}, qsgd) ==");
    let mut rows: Vec<Row> = Vec::new();

    for &s in &[1usize, 2, 4, 8] {
        let mut driver = make_driver(n, d, s, threads);
        let mut rec = Recorder::new();
        // warm the caches + allocator before the measured rounds, and
        // take the profile as a delta past the warm-up so the cold round
        // never skews the recorded critical path
        driver.round(&mut rec);
        let warm = driver.profile().clone();
        let t = std::time::Instant::now();
        for _ in 0..rounds {
            driver.round(&mut rec);
        }
        let wall = t.elapsed().as_secs_f64();
        let profile = driver.profile().clone();
        let stats = driver.traffic();
        let total_rounds = driver.rounds();
        let measured = rounds as f64;
        let row = Row {
            shards: s,
            rounds_per_sec: measured / wall,
            leader_crit_ms: (profile.critical_s - warm.critical_s) / measured * 1e3,
            leader_total_ms: (profile.decode_agg_s - warm.decode_agg_s) / measured * 1e3,
            push_bytes_per_round: stats.bits_of_kind(MessageKind::GradPush) as f64
                / 8.0
                / total_rounds as f64,
        };
        println!(
            "  S={:<2} rounds/s {:>8.2}  leader critical {:>8.4} ms  leader total {:>8.4} ms  push {:>10.0} B/round",
            row.shards, row.rounds_per_sec, row.leader_crit_ms, row.leader_total_ms,
            row.push_bytes_per_round
        );
        rows.push(row);
    }

    let crit1 = rows[0].leader_crit_ms;
    let crit4 = rows
        .iter()
        .find(|r| r.shards == 4)
        .map(|r| r.leader_crit_ms)
        .unwrap_or(f64::NAN);
    let speedup = crit1 / crit4;
    println!("  critical-path speedup S=4 vs S=1: {speedup:.2}x (acceptance bar: >= 2x)");
    println!("== end group ==");

    // hand-rolled JSON (no serde offline); one object per shard row
    let mut json = String::from("{\n  \"bench\": \"shard_leader_critical_path\",\n");
    json.push_str(&format!(
        "  \"quick\": {},\n  \"workers\": {n},\n  \"threads\": {threads},\n  \"d\": {d},\n  \
         \"compressor\": \"qsgd\",\n  \"crit_speedup_s4_vs_s1\": {speedup:.3},\n  \"configs\": [\n",
        quick_mode()
    ));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"shards\": {}, \"rounds_per_sec\": {:.3}, \"leader_crit_ms_per_round\": {:.4}, \
             \"leader_total_ms_per_round\": {:.4}, \"push_bytes_per_round\": {:.1}}}{}\n",
            r.shards,
            r.rounds_per_sec,
            r.leader_crit_ms,
            r.leader_total_ms,
            r.push_bytes_per_round,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::create_dir_all("results").expect("create results dir");
    let path = "results/BENCH_shard.json";
    std::fs::write(path, &json).expect("write BENCH_shard.json");
    println!("wrote {path}");
}
