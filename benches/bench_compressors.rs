//! Compressor throughput on gradient-sized vectors (the L3 hot path that
//! runs once per worker per round). Perf targets in EXPERIMENTS.md §Perf.

use ef_sgd::bench::{black_box, Bench};
use ef_sgd::compress::{Compressor, Identity, Qsgd, RandomK, ScaledSign, Sign, TernGrad, TopK};
use ef_sgd::util::Pcg64;

fn main() {
    let d = 1_000_000;
    let mut rng = Pcg64::seeded(0);
    let mut p = vec![0.0f32; d];
    rng.fill_normal(&mut p, 0.0, 1.0);
    let mut out = vec![0.0f32; d];

    let mut b = Bench::new("compressors (d = 1M f32)");
    let cases: Vec<Box<dyn Compressor>> = vec![
        Box::new(Identity),
        Box::new(Sign),
        Box::new(ScaledSign),
        Box::new(TopK::count(d / 64)),
        Box::new(RandomK::count(d / 64)),
        Box::new(Qsgd::new(4)),
        Box::new(TernGrad),
    ];
    for c in &cases {
        let mut r = Pcg64::seeded(1);
        b.bench_elems(c.name(), d as u64, || {
            c.compress(black_box(&p), black_box(&mut out), &mut r);
        });
    }

    // the norm kernels underlying scaled sign + density
    b.bench_elems("norm1", d as u64, || {
        black_box(ef_sgd::tensor::norm1(black_box(&p)));
    });
    b.bench_elems("density", d as u64, || {
        black_box(ef_sgd::tensor::density(black_box(&p)));
    });
    // the full EF step (compress + residual update), with and without the
    // Fig-2 density instrumentation (an extra L1+L2 pass over p)
    let mut ef = ef_sgd::compress::ErrorFeedback::new(d, Box::new(ScaledSign));
    let mut r = Pcg64::seeded(2);
    b.bench_elems("ef_scaled_sign_step (density on)", d as u64, || {
        ef.step_into(0.01, black_box(&p), black_box(&mut out), &mut r);
    });
    let mut ef2 = ef_sgd::compress::ErrorFeedback::new(d, Box::new(ScaledSign));
    ef2.set_track_density(false);
    b.bench_elems("ef_scaled_sign_step (density off)", d as u64, || {
        ef2.step_into(0.01, black_box(&p), black_box(&mut out), &mut r);
    });
    b.finish();
}
