//! Regenerates §3 counterexamples (CE1-3, Thm I) and times the drivers.
//! Full sizes with BENCH_FULL=1; quick otherwise.
use ef_sgd::bench::Bench;
use ef_sgd::experiments::{self, ExpContext};

fn ctx() -> ExpContext {
    ExpContext {
        quick: std::env::var("BENCH_FULL").map_or(true, |v| v != "1"),
        out_dir: "results".into(),
        ..Default::default()
    }
}

fn main() {
    let mut b = Bench::with_config(
        "paper counterexamples (CE1-3, Thm I)",
        ef_sgd::bench::BenchConfig {
            measure_time: std::time::Duration::from_millis(1),
            warmup_time: std::time::Duration::from_millis(0),
            samples: 1,
        },
    );
    for id in ["ce1", "ce2", "ce3", "thm1"] {
        b.bench(id, || {
            experiments::run(id, &ctx()).expect(id);
        });
    }
    b.finish();
}
