//! Collective latency on the simulated fabric: ring all-reduce vs
//! parameter-server gather at several worker counts.

use ef_sgd::bench::{black_box, Bench};
use ef_sgd::collectives::{ring_allreduce, ring_allreduce_parallel, ParameterServer};
use ef_sgd::compress::wire;
use ef_sgd::net::{Fabric, LinkModel};
use ef_sgd::util::Pcg64;

fn main() {
    let d = 100_000;
    let mut b = Bench::new("collectives (d = 100k f32)");
    for n in [2usize, 4, 8] {
        let mut rng = Pcg64::seeded(n as u64);
        let template: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        b.bench_elems(&format!("ring_allreduce n={n}"), (d * n) as u64, || {
            let fabric = Fabric::new(n, LinkModel::default());
            let mut buffers = template.clone();
            ring_allreduce(&fabric, &mut buffers, 0);
            black_box(&buffers);
        });
        b.bench_elems(
            &format!("ring_allreduce_parallel n={n}"),
            (d * n) as u64,
            || {
                let fabric = Fabric::new(n, LinkModel::default());
                let mut buffers = template.clone();
                ring_allreduce_parallel(&fabric, &mut buffers, 0);
                black_box(&buffers);
            },
        );
        b.bench_elems(&format!("ps_gather_sign n={n}"), (d * n) as u64, || {
            let fabric = Fabric::new(n + 1, LinkModel::default());
            let ps = ParameterServer::new(&fabric);
            for w in 0..n {
                ps.push_grad(&fabric, w, 0, wire::encode_scaled_sign(&template[w]));
            }
            black_box(ps.gather_mean(&fabric, 0, d).expect("ps gather"));
        });
    }
    b.finish();
}
