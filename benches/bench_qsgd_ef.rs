//! Regenerates the paper artifact: Remark 5 unbiased+EF (`repro exp rem5`).
//! Full sizes with BENCH_FULL=1; quick otherwise.
use ef_sgd::bench::Bench;
use ef_sgd::experiments::{self, ExpContext};

fn main() {
    let ctx = ExpContext {
        quick: std::env::var("BENCH_FULL").map_or(true, |v| v != "1"),
        out_dir: "results".into(),
        ..Default::default()
    };
    let mut b = Bench::with_config(
        "Remark 5 unbiased+EF",
        ef_sgd::bench::BenchConfig {
            measure_time: std::time::Duration::from_millis(1),
            warmup_time: std::time::Duration::from_millis(0),
            samples: 1,
        },
    );
    b.bench("rem5", || {
        experiments::run("rem5", &ctx).expect("rem5");
    });
    b.finish();
}
