//! Regenerates Fig 4/6/7 + Tables 1/3/4 (the §6 deep-net simulations).
//! Full sizes with BENCH_FULL=1; quick otherwise.
use ef_sgd::bench::Bench;
use ef_sgd::experiments::{self, ExpContext};

fn main() {
    let ctx = ExpContext {
        quick: std::env::var("BENCH_FULL").map_or(true, |v| v != "1"),
        out_dir: "results".into(),
        ..Default::default()
    };
    let mut b = Bench::with_config(
        "Fig 4/6/7 + Tables 1/3/4 (CIFAR simulations)",
        ef_sgd::bench::BenchConfig {
            measure_time: std::time::Duration::from_millis(1),
            warmup_time: std::time::Duration::from_millis(0),
            samples: 1,
        },
    );
    b.bench("fig4_tables_1_3", || {
        experiments::run("fig4", &ctx).expect("fig4");
    });
    b.bench("fig7_table_4", || {
        experiments::run("fig7", &ctx).expect("fig7");
    });
    b.finish();
}
