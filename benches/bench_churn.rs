//! Elastic-membership benchmark: host-side round throughput of the sync
//! and async engines under seeded fail-stop churn, against the churn-free
//! baseline. The churn machinery (live-set maintenance, per-round event
//! application, epoch bookkeeping, departed-frame filtering) must stay
//! off the hot path when the schedule is inactive and cheap when it is
//! not; this bench puts a number on both. Emits
//! `results/BENCH_churn.json`.

use ef_sgd::bench::{quick_mode, Bench};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::async_driver::AsyncTrainDriver;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::MembershipSchedule;
use ef_sgd::util::Pcg64;

/// Churn horizon: more rounds than any bench run will drive, so the
/// schedule never runs out of events mid-measurement.
const HORIZON: u64 = 100_000;

fn make_workers(n: usize, d: usize) -> Vec<Worker> {
    (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::seeded(100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                64,
                4,
                Pcg64::seeded(id as u64),
            )
        })
        .collect()
}

fn cfg_with(membership: MembershipSchedule, threads: usize) -> DriverConfig {
    DriverConfig {
        steps: usize::MAX, // rounds are driven manually below
        schedule: LrSchedule::constant(0.01),
        membership,
        threads,
        ..Default::default()
    }
}

struct Row {
    engine: &'static str,
    workers: usize,
    rate_milli: u64,
    events: usize,
    rounds_per_sec: f64,
}

fn main() {
    let d = if quick_mode() { 16_384 } else { 262_144 };
    let n = 8usize;
    let mut b = Bench::new(&format!("elastic-membership churn (n = {n}, d = {d})"));
    let mut rows: Vec<Row> = Vec::new();

    // sync engine: churn-free baseline, then crash churn at 2% and 5%
    for &rate_milli in &[0u64, 20, 50] {
        let membership =
            MembershipSchedule::random_churn(7, n, HORIZON, rate_milli as f64 / 1000.0, true);
        let events = membership.events().len();
        let mut driver =
            TrainDriver::new(cfg_with(membership, 4), make_workers(n, d), vec![0.5f32; d]);
        let mut rec = Recorder::new();
        let name = format!("sync round rate={:.3}", rate_milli as f64 / 1000.0);
        let res = b.bench_elems(&name, n as u64, || {
            driver.round(&mut rec);
        });
        rows.push(Row {
            engine: "sync",
            workers: n,
            rate_milli,
            events,
            rounds_per_sec: 1.0 / res.mean.as_secs_f64(),
        });
    }

    // async engine: half quorum, staleness bound 3, same churn flavours
    for &rate_milli in &[0u64, 50] {
        let membership =
            MembershipSchedule::random_churn(7, n, HORIZON, rate_milli as f64 / 1000.0, true);
        let events = membership.events().len();
        let mut driver = AsyncTrainDriver::new(
            cfg_with(membership, 4),
            n / 2,
            3,
            make_workers(n, d),
            vec![0.5f32; d],
        );
        let mut rec = Recorder::new();
        let name = format!("async fold rate={:.3}", rate_milli as f64 / 1000.0);
        let res = b.bench_elems(&name, n as u64, || {
            driver.step_round(&mut rec);
        });
        rows.push(Row {
            engine: "async",
            workers: n,
            rate_milli,
            events,
            rounds_per_sec: 1.0 / res.mean.as_secs_f64(),
        });
    }
    b.finish();

    // hand-rolled JSON (no serde offline); one object per config row
    let mut json = String::from("{\n  \"bench\": \"churn\",\n");
    json.push_str(&format!("  \"quick\": {},\n  \"configs\": [\n", quick_mode()));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"engine\": \"{}\", \"workers\": {}, \"crash_rate\": {:.3}, \
             \"schedule_events\": {}, \"d\": {}, \"rounds_per_sec\": {:.3}}}{}\n",
            r.engine,
            r.workers,
            r.rate_milli as f64 / 1000.0,
            r.events,
            d,
            r.rounds_per_sec,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::create_dir_all("results").expect("create results dir");
    let path = "results/BENCH_churn.json";
    std::fs::write(path, &json).expect("write BENCH_churn.json");
    println!("wrote {path}");
}
