//! PJRT artifact execution latency: the L2/L1 dispatch costs that bound
//! the coordinator's round rate. Skips gracefully without artifacts.

use ef_sgd::bench::{black_box, Bench, BenchConfig};
use ef_sgd::data::tokens::MarkovCorpus;
use ef_sgd::runtime::{LmSession, Runtime};
use ef_sgd::util::Pcg64;
use std::time::Duration;

fn main() {
    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP bench_runtime: {e}");
            return;
        }
    };
    let cfg = BenchConfig {
        measure_time: Duration::from_secs(2),
        warmup_time: Duration::from_millis(200),
        samples: 10,
    };
    let mut b = Bench::with_config("PJRT artifact dispatch", cfg);
    for model in ["tiny", "small"] {
        if rt.model(model).is_err() {
            continue;
        }
        let session = LmSession::open(&rt, model).expect("open");
        let d = session.d();
        let theta = rt.init_params(&session.model).unwrap();
        let corpus = MarkovCorpus::new(session.model.vocab, 3, 0);
        let (bsz, s) = session.model.token_shape();
        let mut rng = Pcg64::seeded(0);
        let tokens = corpus.sample_batch(bsz, s, &mut rng);
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 0.0, 1.0);
        let e = vec![0.0f32; d];

        b.bench_elems(&format!("{model}: lm_step (loss+grad)"), d as u64, || {
            black_box(session.train_step(&theta, &tokens).unwrap());
        });
        b.bench_elems(&format!("{model}: ef_sign kernel"), d as u64, || {
            black_box(session.ef_sign(&g, &e, 0.1).unwrap());
        });
        b.bench_elems(&format!("{model}: lm_step_ef (fused)"), d as u64, || {
            black_box(session.train_step_ef(&theta, &e, &tokens, 0.1).unwrap());
        });
        b.bench_elems(&format!("{model}: density kernel"), d as u64, || {
            black_box(session.density(&g).unwrap());
        });
        b.bench_elems(&format!("{model}: apply_update"), d as u64, || {
            black_box(session.apply_update(&theta, &g).unwrap());
        });
        // rust-native EF step for comparison (is PJRT dispatch the bottleneck?)
        let mut ef = ef_sgd::compress::ErrorFeedback::new(
            d,
            Box::new(ef_sgd::compress::ScaledSign),
        );
        let mut out = vec![0.0f32; d];
        let mut r2 = Pcg64::seeded(1);
        b.bench_elems(&format!("{model}: rust-native ef step"), d as u64, || {
            ef.step_into(0.1, black_box(&g), black_box(&mut out), &mut r2);
        });
    }
    b.finish();
}
