//! Wire codec throughput: the bit-packing encode/decode on the
//! coordinator's critical path.

use ef_sgd::bench::{black_box, Bench};
use ef_sgd::compress::wire;
use ef_sgd::compress::{Compressor, Qsgd, TernGrad, TopK};
use ef_sgd::util::Pcg64;

fn main() {
    let d = 1_000_000;
    let mut rng = Pcg64::seeded(0);
    let mut p = vec![0.0f32; d];
    rng.fill_normal(&mut p, 0.0, 1.0);

    let mut b = Bench::new("wire codecs (d = 1M f32)");
    b.bench_bytes("encode_dense", 4 * d as u64, || {
        black_box(wire::encode_dense(black_box(&p)));
    });
    b.bench_bytes("encode_scaled_sign", 4 * d as u64, || {
        black_box(wire::encode_scaled_sign(black_box(&p)));
    });
    let enc_sign = wire::encode_scaled_sign(&p);
    b.bench_bytes("decode_scaled_sign", 4 * d as u64, || {
        black_box(wire::decode_scaled_sign(black_box(&enc_sign)).unwrap());
    });
    let mut acc = vec![0.0f32; d];
    b.bench_bytes("decode_scaled_sign_add (PS hot path)", 4 * d as u64, || {
        wire::decode_scaled_sign_add(black_box(&enc_sign), black_box(&mut acc)).unwrap();
    });
    let sparse = TopK::count(d / 64).compress_vec(&p, &mut Pcg64::seeded(1));
    b.bench_elems("encode_sparse (k = d/64)", (d / 64) as u64, || {
        black_box(wire::encode_sparse(black_box(&sparse)));
    });
    let enc_sparse = wire::encode_sparse(&sparse);
    b.bench_elems("decode_sparse", (d / 64) as u64, || {
        black_box(wire::decode_sparse(black_box(&enc_sparse)).unwrap());
    });
    let tern = TernGrad.compress_vec(&p, &mut Pcg64::seeded(2));
    b.bench_bytes("encode_ternary", 4 * d as u64, || {
        black_box(wire::encode_ternary(black_box(&tern)));
    });
    let qsgd = Qsgd::new(4).compress_vec(&p, &mut Pcg64::seeded(3));
    let qnorm = ef_sgd::tensor::norm2(&p) as f32;
    b.bench_bytes("encode_qsgd (s = 4, Elias pack)", 4 * d as u64, || {
        black_box(wire::encode_qsgd(black_box(&qsgd), qnorm, 4));
    });
    let enc_qsgd = wire::encode_qsgd(&qsgd, qnorm, 4);
    println!(
        "  (qsgd frame: {:.2} bits/coord vs 32 dense)",
        enc_qsgd.bits as f64 / d as f64
    );
    b.bench_bytes("decode_qsgd", 4 * d as u64, || {
        black_box(wire::decode_qsgd(black_box(&enc_qsgd)).unwrap());
    });
    b.bench_bytes("decode_qsgd_add (PS hot path)", 4 * d as u64, || {
        wire::decode_qsgd_add(black_box(&enc_qsgd), black_box(&mut acc)).unwrap();
    });
    b.finish();
}
