//! Leader hot-path benchmark: full synchronous rounds at n ∈ {4, 16}
//! workers, separating the leader's decode+aggregate wall-clock (via
//! [`LeaderProfile`]) from whole-round throughput, for the scaled-sign and
//! Elias-packed QSGD wire formats, plus a decode-kernel microbench that
//! pits the vectorized sign/QSGD decoders against their per-bit scalar
//! references (bitwise parity asserted, speedup reported — CI requires
//! ≥ 2x). Emits `results/BENCH_leader.json` (rounds/sec, bytes/round,
//! kernel speedups) so the perf trajectory of the gather→decode→aggregate
//! path is tracked from this PR onward.

use ef_sgd::bench::{quick_mode, Bench};
use ef_sgd::compress::wire::{self, Encoded};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::MessageKind;
use ef_sgd::util::Pcg64;

fn make_driver(n: usize, d: usize, kind: CompressorKind, threads: usize) -> TrainDriver {
    let workers: Vec<Worker> = (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::seeded(100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                kind,
                64,
                4,
                Pcg64::seeded(id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps: 0, // rounds are driven manually below
        schedule: LrSchedule::constant(0.01),
        threads,
        ..Default::default()
    };
    TrainDriver::new(cfg, workers, vec![0.5f32; d])
}

struct Row {
    workers: usize,
    threads: usize,
    d: usize,
    compressor: &'static str,
    rounds_per_sec: f64,
    leader_agg_ms_per_round: f64,
    push_bytes_per_round: f64,
    push_mean_frame_bits: f64,
}

// ---------------------------------------------------------------- kernels
//
// Scalar baselines for the decode kernels, mirroring the `#[cfg(test)]`
// bitwise-parity references in `compress::wire`: every bit flows through a
// per-bit reader with a branchy sign select — the shape of the decoder
// before the windowed BitReader and the branch-free sign unpack. The bench
// asserts bitwise parity first, then reports vectorized-vs-scalar speedup
// (the CI bar is ≥ 2x on these decode-dominated kernels).

struct ScalarBitReader<'a> {
    bytes: &'a [u8],
    pos: u64,
}

impl<'a> ScalarBitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    fn read_bit(&mut self) -> Option<bool> {
        let idx = (self.pos / 8) as usize;
        if idx >= self.bytes.len() {
            return None;
        }
        let bit = (self.bytes[idx] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    fn read_bits32(&mut self, n: u32) -> Option<u32> {
        let mut v = 0u32;
        for i in 0..n {
            v |= u32::from(self.read_bit()?) << i;
        }
        Some(v)
    }

    fn read_elias_gamma(&mut self) -> Option<u64> {
        let mut zeros = 0u32;
        while !self.read_bit()? {
            zeros += 1;
            if zeros > 63 {
                return None;
            }
        }
        let mut x = 1u64;
        for _ in 0..zeros {
            x = (x << 1) | u64::from(self.read_bit()?);
        }
        Some(x)
    }
}

fn scalar_sign_decode_add(e: &Encoded, acc: &mut [f32]) {
    let b = &e.bytes;
    let scale = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    let mut r = ScalarBitReader::new(&b[4..]);
    for a in acc.iter_mut() {
        let bit = r.read_bit().expect("sign bit");
        *a += if bit { scale } else { -scale };
    }
}

fn scalar_qsgd_decode_add(e: &Encoded, acc: &mut [f32]) {
    let mut r = ScalarBitReader::new(&e.bytes);
    let norm = f32::from_bits(r.read_bits32(32).expect("norm"));
    let s = r.read_bits32(8).expect("levels");
    let s_f = s as f32;
    for a in acc.iter_mut() {
        let l = r.read_elias_gamma().expect("level") - 1;
        if l > 0 {
            let mag = norm * l as f32 / s_f;
            if r.read_bit().expect("sign") {
                *a -= mag;
            } else {
                *a += mag;
            }
        }
    }
}

/// Mean seconds per call after one warm-up invocation.
fn kernel_time<F: FnMut()>(reps: u32, mut f: F) -> f64 {
    f();
    let t = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    t.elapsed().as_secs_f64() / f64::from(reps)
}

struct KernelRows {
    d: usize,
    sign_mcoords_per_sec: f64,
    sign_decode_speedup: f64,
    qsgd_mcoords_per_sec: f64,
    qsgd_decode_speedup: f64,
}

fn bench_kernels(d: usize) -> KernelRows {
    let reps = if quick_mode() { 400u32 } else { 60 };
    let mut rng = Pcg64::seeded(42);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 0.0, 1.0);
    let sign_frame = wire::encode_scaled_sign(&v);
    // the qsgd input carries a deliberate spread of levels (level-0-heavy,
    // like real gradients, but with enough multi-bit gamma codes to
    // exercise the windowed reader) as exactly representable ±norm·l/s
    // values, so the frame round-trips bit-faithfully
    let s = 4u32;
    let norm = 1.0f32;
    let mut q = vec![0.0f32; d];
    for (i, x) in q.iter_mut().enumerate() {
        let l = [0.0f32, 0.0, 1.0, 0.0, 2.0, 0.0, 3.0, 4.0][i % 8];
        let mag = norm * l / s as f32;
        *x = if i % 3 == 0 { -mag } else { mag };
    }
    let qsgd_frame = wire::encode_qsgd(&q, norm, s);

    // bitwise parity before timing: the speedup is only meaningful if the
    // two paths produce the identical accumulator
    let mut fast = vec![0.25f32; d];
    let mut slow = fast.clone();
    wire::decode_scaled_sign_add(&sign_frame, &mut fast).expect("decode");
    scalar_sign_decode_add(&sign_frame, &mut slow);
    assert!(
        fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()),
        "sign decode parity"
    );
    wire::decode_qsgd_add(&qsgd_frame, &mut fast).expect("decode");
    scalar_qsgd_decode_add(&qsgd_frame, &mut slow);
    assert!(
        fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()),
        "qsgd decode parity"
    );

    let mut acc = vec![0.0f32; d];
    let t_sign_vec = kernel_time(reps, || {
        wire::decode_scaled_sign_add(std::hint::black_box(&sign_frame), &mut acc).expect("decode");
    });
    let t_sign_scalar = kernel_time(reps, || {
        scalar_sign_decode_add(std::hint::black_box(&sign_frame), &mut acc);
    });
    std::hint::black_box(&acc);
    acc.fill(0.0);
    let t_qsgd_vec = kernel_time(reps, || {
        wire::decode_qsgd_add(std::hint::black_box(&qsgd_frame), &mut acc).expect("decode");
    });
    let t_qsgd_scalar = kernel_time(reps, || {
        scalar_qsgd_decode_add(std::hint::black_box(&qsgd_frame), &mut acc);
    });
    std::hint::black_box(&acc);

    let rows = KernelRows {
        d,
        sign_mcoords_per_sec: d as f64 / t_sign_vec / 1e6,
        sign_decode_speedup: t_sign_scalar / t_sign_vec,
        qsgd_mcoords_per_sec: d as f64 / t_qsgd_vec / 1e6,
        qsgd_decode_speedup: t_qsgd_scalar / t_qsgd_vec,
    };
    println!("\n== bench group: decode kernels, vectorized vs per-bit scalar (d = {d}) ==");
    println!(
        "  sign  {:>9.1} Mcoord/s  speedup {:>6.2}x   (word unpack + branch-free ±scale)",
        rows.sign_mcoords_per_sec, rows.sign_decode_speedup
    );
    println!(
        "  qsgd  {:>9.1} Mcoord/s  speedup {:>6.2}x   (windowed Elias-gamma reader)",
        rows.qsgd_mcoords_per_sec, rows.qsgd_decode_speedup
    );
    println!("== end group ==");
    rows
}

fn main() {
    let d = if quick_mode() { 16_384 } else { 262_144 };
    let mut b = Bench::new(&format!("leader decode+aggregate (d = {d})"));
    let mut rows: Vec<Row> = Vec::new();

    for &(n, threads) in &[(4usize, 4usize), (16, 8)] {
        for kind in [CompressorKind::ScaledSign, CompressorKind::Qsgd] {
            let mut driver = make_driver(n, d, kind, threads);
            let mut rec = Recorder::new();
            let name = format!("round n={n} threads={threads} {}", kind.name());
            let res = b.bench_elems(&name, n as u64, || {
                driver.round(&mut rec);
            });
            let rounds = driver.rounds();
            let profile = driver.profile().clone();
            let stats = driver.traffic();
            let push_bits = stats.bits_of_kind(MessageKind::GradPush);
            rows.push(Row {
                workers: n,
                threads,
                d,
                compressor: kind.name(),
                rounds_per_sec: 1.0 / res.mean.as_secs_f64(),
                leader_agg_ms_per_round: profile.mean_round_s() * 1e3,
                push_bytes_per_round: push_bits as f64 / 8.0 / rounds as f64,
                push_mean_frame_bits: stats.mean_msg_bits(MessageKind::GradPush),
            });
        }
    }
    b.finish();

    let kernels = bench_kernels(d);

    // hand-rolled JSON (no serde offline); one object per config row
    let mut json = String::from("{\n  \"bench\": \"leader_decode_aggregate\",\n");
    json.push_str(&format!("  \"quick\": {},\n", quick_mode()));
    json.push_str(&format!(
        "  \"kernels\": {{\"d\": {}, \"sign_mcoords_per_sec\": {:.1}, \
         \"sign_decode_speedup\": {:.3}, \"qsgd_mcoords_per_sec\": {:.1}, \
         \"qsgd_decode_speedup\": {:.3}}},\n",
        kernels.d,
        kernels.sign_mcoords_per_sec,
        kernels.sign_decode_speedup,
        kernels.qsgd_mcoords_per_sec,
        kernels.qsgd_decode_speedup
    ));
    json.push_str("  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {}, \"threads\": {}, \"d\": {}, \"compressor\": \"{}\", \
             \"rounds_per_sec\": {:.3}, \"leader_agg_ms_per_round\": {:.4}, \
             \"push_bytes_per_round\": {:.1}, \"push_mean_frame_bits\": {:.1}}}{}\n",
            r.workers,
            r.threads,
            r.d,
            r.compressor,
            r.rounds_per_sec,
            r.leader_agg_ms_per_round,
            r.push_bytes_per_round,
            r.push_mean_frame_bits,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::create_dir_all("results").expect("create results dir");
    let path = "results/BENCH_leader.json";
    std::fs::write(path, &json).expect("write BENCH_leader.json");
    println!("wrote {path}");
}
