//! Leader hot-path benchmark: full synchronous rounds at n ∈ {4, 16}
//! workers, separating the leader's decode+aggregate wall-clock (via
//! [`LeaderProfile`]) from whole-round throughput, for the scaled-sign and
//! Elias-packed QSGD wire formats. Emits `results/BENCH_leader.json`
//! (rounds/sec, bytes/round) so the perf trajectory of the
//! gather→decode→aggregate path is tracked from this PR onward.

use ef_sgd::bench::{quick_mode, Bench};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::MessageKind;
use ef_sgd::util::Pcg64;

fn make_driver(n: usize, d: usize, kind: CompressorKind, threads: usize) -> TrainDriver {
    let workers: Vec<Worker> = (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.0),
                    Pcg64::seeded(100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                kind,
                64,
                4,
                Pcg64::seeded(id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps: 0, // rounds are driven manually below
        schedule: LrSchedule::constant(0.01),
        threads,
        ..Default::default()
    };
    TrainDriver::new(cfg, workers, vec![0.5f32; d])
}

struct Row {
    workers: usize,
    threads: usize,
    d: usize,
    compressor: &'static str,
    rounds_per_sec: f64,
    leader_agg_ms_per_round: f64,
    push_bytes_per_round: f64,
    push_mean_frame_bits: f64,
}

fn main() {
    let d = if quick_mode() { 16_384 } else { 262_144 };
    let mut b = Bench::new(&format!("leader decode+aggregate (d = {d})"));
    let mut rows: Vec<Row> = Vec::new();

    for &(n, threads) in &[(4usize, 4usize), (16, 8)] {
        for kind in [CompressorKind::ScaledSign, CompressorKind::Qsgd] {
            let mut driver = make_driver(n, d, kind, threads);
            let mut rec = Recorder::new();
            let name = format!("round n={n} threads={threads} {}", kind.name());
            let res = b.bench_elems(&name, n as u64, || {
                driver.round(&mut rec);
            });
            let rounds = driver.rounds();
            let profile = driver.profile().clone();
            let stats = driver.traffic();
            let push_bits = stats.bits_of_kind(MessageKind::GradPush);
            rows.push(Row {
                workers: n,
                threads,
                d,
                compressor: kind.name(),
                rounds_per_sec: 1.0 / res.mean.as_secs_f64(),
                leader_agg_ms_per_round: profile.mean_round_s() * 1e3,
                push_bytes_per_round: push_bits as f64 / 8.0 / rounds as f64,
                push_mean_frame_bits: stats.mean_msg_bits(MessageKind::GradPush),
            });
        }
    }
    b.finish();

    // hand-rolled JSON (no serde offline); one object per config row
    let mut json = String::from("{\n  \"bench\": \"leader_decode_aggregate\",\n");
    json.push_str(&format!("  \"quick\": {},\n  \"configs\": [\n", quick_mode()));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {}, \"threads\": {}, \"d\": {}, \"compressor\": \"{}\", \
             \"rounds_per_sec\": {:.3}, \"leader_agg_ms_per_round\": {:.4}, \
             \"push_bytes_per_round\": {:.1}, \"push_mean_frame_bits\": {:.1}}}{}\n",
            r.workers,
            r.threads,
            r.d,
            r.compressor,
            r.rounds_per_sec,
            r.leader_agg_ms_per_round,
            r.push_bytes_per_round,
            r.push_mean_frame_bits,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::create_dir_all("results").expect("create results dir");
    let path = "results/BENCH_leader.json";
    std::fs::write(path, &json).expect("write BENCH_leader.json");
    println!("wrote {path}");
}
