//! Offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate links against `xla_extension` (PJRT CPU client),
//! which cannot be fetched or built in this offline environment. This stub
//! keeps the `runtime` layer compiling with the same API surface; every
//! device entry point returns a descriptive error, so `Runtime::load`
//! fails cleanly and all artifact-dependent tests and experiments skip,
//! exactly as they do when `make artifacts` has not been run.
//!
//! [`Literal`] is implemented for real (host-side typed buffers), since
//! argument marshalling and its unit tests do not need a device. All types
//! here are plain data and therefore `Send + Sync`, which is what lets the
//! coordinator share sessions across worker threads; swap in the real
//! bindings and the non-`Send` PJRT handles must stay on one thread (the
//! `--threads 1` path).

use std::fmt;

/// Stub error type: always a plain message.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "XLA/PJRT unavailable: {what} (offline `xla` stub — link the real \
         xla_extension bindings to enable the PJRT runtime)"
    ))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + sealed::Sealed {
    fn into_data(v: Vec<Self>) -> Data;
    fn slice(data: &Data) -> Option<&[Self]>;
}

/// Typed storage backing a [`Literal`].
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NativeType for f32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn slice(data: &Data) -> Option<&[Self]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn slice(data: &Data) -> Option<&[Self]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host-side typed tensor (the only fully functional stub type).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::into_data(v.to_vec()),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: vec![],
            data: T::into_data(vec![v]),
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: literal has {} elements, target shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::slice(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("literal dtype mismatch or empty".to_string()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }

    /// Unpack a tuple literal. The stub never produces tuples (no device
    /// execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub: never constructible from text).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle (stub: creation always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (stub: never constructible).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (stub: never constructible).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_roundtrip() {
        let l = Literal::vec1(&[1.0f32, -2.0, 3.5]);
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.5]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_scalar_and_first() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.element_count(), 1);
        let v: i32 = l.get_first_element().unwrap();
        assert_eq!(v, 7);
        assert!(l.shape().is_empty());
    }

    #[test]
    fn reshape_checks_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn device_paths_fail_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
