//! Minimal offline stand-in for the `anyhow` crate.
//!
//! crates.io is unreachable in this build environment, so this vendored
//! crate provides the subset of the `anyhow` API the workspace uses: the
//! [`Error`] type with context chaining, the [`Context`] extension trait,
//! the [`anyhow!`] / [`bail!`] macros, and the [`Result`] alias. Display
//! semantics match upstream: `{}` prints the outermost message, `{:#}`
//! prints the whole chain separated by `: `, and `{:?}` prints the
//! message followed by a `Caused by:` list.

use std::fmt;

/// A dynamically typed error with a chain of context messages.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            cause: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.cause;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.cause;
        }
        out
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = &self.cause;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.cause;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.cause.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = &self.cause;
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = &e.cause;
            }
        }
        Ok(())
    }
}

// Matches upstream anyhow: every std error converts into `Error` with its
// source chain captured. `Error` itself deliberately does NOT implement
// `std::error::Error`, which keeps this blanket impl coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        fn capture(src: Option<&(dyn std::error::Error + 'static)>) -> Option<Box<Error>> {
            src.map(|s| {
                Box::new(Error {
                    msg: s.to_string(),
                    cause: capture(s.source()),
                })
            })
        }
        Error {
            msg: e.to_string(),
            cause: capture(e.source()),
        }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain(), vec!["outer", "missing file"]);

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }

    #[test]
    fn macros_build_errors() {
        fn fails() -> Result<()> {
            bail!("bad value {}", 7);
        }
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "bad value 7");
        let e2 = anyhow!("plain");
        assert_eq!(e2.root_cause(), "plain");
    }

    #[test]
    fn debug_shows_cause_list() {
        let e: Error = io_err().into();
        let e = e.context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }
}
