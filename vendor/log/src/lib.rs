//! Minimal offline stand-in for the `log` facade crate.
//!
//! Provides the subset of the `log` API this workspace uses: the five
//! level macros, [`Level`] / [`LevelFilter`], [`Record`] / [`Metadata`],
//! the [`Log`] trait, and the `set_logger` / `set_max_level` globals.
//! Before a logger is installed every macro is a cheap no-op.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Severity of a log record (most to least severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter; `Off` disables everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Static facts about a record (level + target).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log message with its metadata and formatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        false
    }
    fn log(&self, _record: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, or a no-op sink if none was set.
pub fn logger() -> &'static dyn Log {
    LOGGER.get().copied().unwrap_or(&NOP)
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: filter by max level, then dispatch to the logger.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level <= max_level() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        logger().log(&record);
    }
}

#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, $target, ::core::format_args!($($arg)+))
    };
    ($lvl:expr, $($arg:tt)+) => {
        $crate::log!(target: ::core::module_path!(), $lvl, $($arg)+)
    };
}

#[macro_export]
macro_rules! error {
    (target: $target:expr, $($arg:tt)+) => { $crate::log!(target: $target, $crate::Level::Error, $($arg)+) };
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    (target: $target:expr, $($arg:tt)+) => { $crate::log!(target: $target, $crate::Level::Warn, $($arg)+) };
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    (target: $target:expr, $($arg:tt)+) => { $crate::log!(target: $target, $crate::Level::Info, $($arg)+) };
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    (target: $target:expr, $($arg:tt)+) => { $crate::log!(target: $target, $crate::Level::Debug, $($arg)+) };
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    (target: $target:expr, $($arg:tt)+) => { $crate::log!(target: $target, $crate::Level::Trace, $($arg)+) };
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(!(Level::Trace <= LevelFilter::Off));
    }

    #[test]
    fn macros_are_safe_without_logger() {
        // No logger installed in this test binary: must be a no-op.
        info!("hello {}", 1);
        error!(target: "custom", "boom {x}", x = 2);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
