"""Layer-2: decoder-only transformer LM in JAX, over a *flat* parameter
vector.

The whole model is a function of a single ``theta: f32[d]`` so that the Rust
coordinator sees exactly the object the paper's algorithms operate on — one
flat gradient vector per worker, fed to the compression kernels and the
error-feedback state. ``param_spec`` defines the layout; ``unflatten``
carves ``theta`` into weight views inside the traced function (zero-copy
slices under XLA).

Artifacts lowered from here (see ``aot.py``):
  lm_step   (theta, tokens) -> (loss, grad)      value_and_grad of the LM
  lm_eval   (theta, tokens) -> loss
  ef_sign   (g, e, gamma)   -> (delta, e_new)    calls the L1 Pallas kernel
  lm_step_ef fused: train step + EF-sign compression in one executable
"""

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ef_sign

# --------------------------------------------------------------------------
# Configuration


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters; all artifact shapes derive from this."""

    name: str
    vocab: int
    dim: int
    layers: int
    heads: int
    seq: int          # context length (tokens per example = seq + 1)
    batch: int        # per-worker microbatch
    mlp_mult: int = 4

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


# The configs shipped by `make artifacts`. "tiny" is the pytest / cargo-test
# config; "small" is the end-to-end training run. Larger configs (e.g. the
# 100M-parameter one in configs/transformer_100m.toml) use the same code but
# are not AOT-compiled by default — CPU-PJRT wallclock, not code, is the
# limit.
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=64, dim=32, layers=2, heads=2, seq=32, batch=4),
    "small": ModelConfig("small", vocab=256, dim=128, layers=4, heads=4, seq=64, batch=8),
}


def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list defining the flat layout of theta."""
    spec = [
        ("embed", (cfg.vocab, cfg.dim)),
        ("pos", (cfg.seq, cfg.dim)),
    ]
    for i in range(cfg.layers):
        spec += [
            (f"l{i}.ln1.scale", (cfg.dim,)),
            (f"l{i}.ln1.bias", (cfg.dim,)),
            (f"l{i}.attn.wq", (cfg.dim, cfg.dim)),
            (f"l{i}.attn.wk", (cfg.dim, cfg.dim)),
            (f"l{i}.attn.wv", (cfg.dim, cfg.dim)),
            (f"l{i}.attn.wo", (cfg.dim, cfg.dim)),
            (f"l{i}.ln2.scale", (cfg.dim,)),
            (f"l{i}.ln2.bias", (cfg.dim,)),
            (f"l{i}.mlp.w1", (cfg.dim, cfg.mlp_mult * cfg.dim)),
            (f"l{i}.mlp.b1", (cfg.mlp_mult * cfg.dim,)),
            (f"l{i}.mlp.w2", (cfg.mlp_mult * cfg.dim, cfg.dim)),
            (f"l{i}.mlp.b2", (cfg.dim,)),
        ]
    spec += [
        ("lnf.scale", (cfg.dim,)),
        ("lnf.bias", (cfg.dim,)),
        ("head", (cfg.dim, cfg.vocab)),
    ]
    return spec


def num_params(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_spec(cfg))


def unflatten(theta, cfg: ModelConfig):
    """Carve the flat theta into a dict of shaped views."""
    params = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        params[name] = theta[off : off + n].reshape(shape)
        off += n
    return params


def init_params(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """GPT-2-style init, returned as the flat f32 vector."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        if name.endswith((".bias", ".b1", ".b2")) or name == "pos":
            w = np.zeros(n, dtype=np.float32)
        elif name.endswith(".scale"):
            w = np.ones(n, dtype=np.float32)
        elif name.endswith(".wo") or name.endswith(".w2"):
            # residual-branch projections get the 1/sqrt(2*layers) shrink
            std = 0.02 / math.sqrt(2.0 * cfg.layers)
            w = rng.normal(0.0, std, n).astype(np.float32)
        else:
            w = rng.normal(0.0, 0.02, n).astype(np.float32)
        chunks.append(w)
    return np.concatenate(chunks)


# --------------------------------------------------------------------------
# Forward pass


def _layer_norm(x, scale, bias, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * scale + bias


def _attention(x, p, prefix, cfg: ModelConfig):
    b, s, _ = x.shape
    h, hd = cfg.heads, cfg.head_dim

    def split(v):
        return v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # (b,h,s,hd)

    q = split(x @ p[f"{prefix}.wq"])
    k = split(x @ p[f"{prefix}.wk"])
    v = split(x @ p[f"{prefix}.wv"])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask, logits, -1e30)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.dim)
    return out @ p[f"{prefix}.wo"]


def forward(theta, tokens, cfg: ModelConfig):
    """Logits for next-token prediction. tokens: i32[batch, seq]."""
    p = unflatten(theta, cfg)
    x = p["embed"][tokens] + p["pos"][None, : tokens.shape[1]]
    for i in range(cfg.layers):
        x = x + _attention(
            _layer_norm(x, p[f"l{i}.ln1.scale"], p[f"l{i}.ln1.bias"]),
            p,
            f"l{i}.attn",
            cfg,
        )
        hmid = _layer_norm(x, p[f"l{i}.ln2.scale"], p[f"l{i}.ln2.bias"])
        hmid = jax.nn.gelu(hmid @ p[f"l{i}.mlp.w1"] + p[f"l{i}.mlp.b1"])
        x = x + hmid @ p[f"l{i}.mlp.w2"] + p[f"l{i}.mlp.b2"]
    x = _layer_norm(x, p["lnf.scale"], p["lnf.bias"])
    return x @ p["head"]


def loss_fn(theta, tokens, cfg: ModelConfig):
    """Mean next-token cross-entropy. tokens: i32[batch, seq+1]."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = forward(theta, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# The functions that become artifacts


def lm_step(theta, tokens, cfg: ModelConfig):
    """(loss, grad) — the per-worker training step."""
    loss, grad = jax.value_and_grad(loss_fn)(theta, tokens, cfg)
    return loss, grad


def lm_eval(theta, tokens, cfg: ModelConfig):
    return (loss_fn(theta, tokens, cfg),)


def ef_sign_artifact(g, e, gamma):
    """The L1 Pallas kernel wrapped as its own executable."""
    return ef_sign.ef_sign_step(g, e, gamma)


def ef_topk_artifact(g, e, gamma, k):
    return ef_sign.ef_topk_step(g, e, gamma, k=k)


def density_artifact(v):
    return (ef_sign.density(v),)


def apply_update(theta, delta):
    return (theta - delta,)


def lm_step_ef(theta, e, tokens, gamma, cfg: ModelConfig):
    """Fused: train step + EF-sign compression in one executable.

    Used by the single-worker fast path: one PJRT execute per step instead
    of two, and the gradient never round-trips through host memory.
    Returns (loss, delta, e_new).
    """
    loss, grad = jax.value_and_grad(loss_fn)(theta, tokens, cfg)
    delta, e_new = ef_sign.ef_sign_step(grad, e, gamma)
    return loss, delta, e_new


def make_example_tokens(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq + 1), dtype=np.int32)
