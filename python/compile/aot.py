"""AOT lowering: JAX functions -> HLO *text* artifacts for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out ../artifacts [--configs tiny,small]

Emits, per model config:
  lm_step_<cfg>.hlo.txt      (theta f32[d], tokens i32[B,S+1]) -> (loss, grad)
  lm_eval_<cfg>.hlo.txt      (theta, tokens) -> (loss,)
  lm_step_ef_<cfg>.hlo.txt   (theta, e, tokens, gamma) -> (loss, delta, e_new)
  ef_sign_<cfg>.hlo.txt      (g f32[d], e f32[d], gamma f32[1]) -> (delta, e_new)
  ef_topk_<cfg>.hlo.txt      same, top-k with k = max(1, d/64)
  density_<cfg>.hlo.txt      (v f32[d]) -> (phi,)
  apply_update_<cfg>.hlo.txt (theta, delta) -> (theta',)
  init_params_<cfg>.bin      raw little-endian f32 initial parameters
plus a manifest.json the Rust artifact registry reads.
"""

import argparse
import hashlib
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower(fn, *args):
    return to_hlo_text(jax.jit(fn).lower(*args))


def emit(out_dir, name, text, entry):
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    entry["file"] = name
    entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
    entry["bytes"] = len(text)
    return entry


def arg(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def build_config(cfg: M.ModelConfig, out_dir: str):
    d = M.num_params(cfg)
    tok_shape = (cfg.batch, cfg.seq + 1)
    k = max(1, d // 64)
    arts = []

    print(f"[aot] config {cfg.name}: d={d} tokens={tok_shape}")

    theta = spec((d,))
    vec = spec((d,))
    gamma = spec((1,))
    tokens = spec(tok_shape, jnp.int32)

    arts.append(
        emit(
            out_dir,
            f"lm_step_{cfg.name}.hlo.txt",
            lower(partial(M.lm_step, cfg=cfg), theta, tokens),
            {
                "name": f"lm_step_{cfg.name}",
                "inputs": [arg((d,)), arg(tok_shape, "i32")],
                "outputs": [arg(()), arg((d,))],
            },
        )
    )
    arts.append(
        emit(
            out_dir,
            f"lm_eval_{cfg.name}.hlo.txt",
            lower(partial(M.lm_eval, cfg=cfg), theta, tokens),
            {
                "name": f"lm_eval_{cfg.name}",
                "inputs": [arg((d,)), arg(tok_shape, "i32")],
                "outputs": [arg(())],
            },
        )
    )
    arts.append(
        emit(
            out_dir,
            f"lm_step_ef_{cfg.name}.hlo.txt",
            lower(partial(M.lm_step_ef, cfg=cfg), theta, vec, tokens, gamma),
            {
                "name": f"lm_step_ef_{cfg.name}",
                "inputs": [arg((d,)), arg((d,)), arg(tok_shape, "i32"), arg((1,))],
                "outputs": [arg(()), arg((d,)), arg((d,))],
            },
        )
    )
    arts.append(
        emit(
            out_dir,
            f"ef_sign_{cfg.name}.hlo.txt",
            lower(M.ef_sign_artifact, vec, vec, gamma),
            {
                "name": f"ef_sign_{cfg.name}",
                "inputs": [arg((d,)), arg((d,)), arg((1,))],
                "outputs": [arg((d,)), arg((d,))],
            },
        )
    )
    arts.append(
        emit(
            out_dir,
            f"ef_topk_{cfg.name}.hlo.txt",
            lower(partial(M.ef_topk_artifact, k=k), vec, vec, gamma),
            {
                "name": f"ef_topk_{cfg.name}",
                "inputs": [arg((d,)), arg((d,)), arg((1,))],
                "outputs": [arg((d,)), arg((d,))],
                "k": k,
            },
        )
    )
    arts.append(
        emit(
            out_dir,
            f"density_{cfg.name}.hlo.txt",
            lower(M.density_artifact, vec),
            {
                "name": f"density_{cfg.name}",
                "inputs": [arg((d,))],
                "outputs": [arg(())],
            },
        )
    )
    arts.append(
        emit(
            out_dir,
            f"apply_update_{cfg.name}.hlo.txt",
            lower(M.apply_update, theta, vec),
            {
                "name": f"apply_update_{cfg.name}",
                "inputs": [arg((d,)), arg((d,))],
                "outputs": [arg((d,))],
            },
        )
    )

    init = M.init_params(cfg, seed=0)
    init_name = f"init_params_{cfg.name}.bin"
    init.tofile(os.path.join(out_dir, init_name))

    return {
        "name": cfg.name,
        "d": d,
        "vocab": cfg.vocab,
        "dim": cfg.dim,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "topk_k": k,
        "init_params": init_name,
        "artifacts": arts,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "configs": []}
    for name in args.configs.split(","):
        cfg = M.CONFIGS[name.strip()]
        manifest["configs"].append(build_config(cfg, args.out))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(manifest['configs'])} configs to {args.out}")


if __name__ == "__main__":
    main()
