"""L1: Pallas kernels for the paper's compute hot-spot (EF compression)."""

from .ef_sign import ef_sign_step, ef_topk_step, density, BLOCK  # noqa: F401
from . import ref  # noqa: F401
