"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: deliberately naive, no tiling, no
tricks. pytest checks the Pallas implementations against these with
``assert_allclose`` across a hypothesis-driven sweep of shapes and values.
"""

import jax
import jax.numpy as jnp


def ef_sign_step_ref(g, e, gamma):
    """Algorithm 1 lines 4-7, literally."""
    p = gamma[0] * g + e
    d = p.shape[0]
    scale = jnp.sum(jnp.abs(p)) / d
    delta = scale * jnp.sign(p)
    return delta, p - delta


def ef_topk_step_ref(g, e, gamma, k):
    """Threshold semantics: keep every |p_i| >= (k-th largest |p|)."""
    p = gamma[0] * g + e
    thr = jnp.sort(jnp.abs(p))[p.shape[0] - k]
    delta = jnp.where(jnp.abs(p) >= thr, p, 0.0)
    return delta, p - delta


def density_ref(v):
    """phi(v) = ||v||_1^2 / (d ||v||_2^2); 1.0 for the zero vector."""
    d = v.shape[0]
    l1 = jnp.sum(jnp.abs(v))
    l2 = jnp.sum(v * v)
    return jnp.where(l2 > 0, l1 * l1 / (d * l2), 1.0)


def scaled_sign(v):
    """The paper's compressor C(v) = (||v||_1 / d) sign(v) (Lemma 8)."""
    d = v.shape[0]
    return (jnp.sum(jnp.abs(v)) / d) * jnp.sign(v)
