"""Layer-1 Pallas kernels: the fused error-feedback scaled-sign step.

This is the compression hot-spot of the paper (Algorithm 1, EF-SIGNSGD):

    p     = gamma * g + e          (error correction)
    delta = (||p||_1 / d) sign(p)  (compression)
    e'    = p - delta              (residual update)

The computation is bandwidth-bound (two passes over the gradient, no MXU
work), so the TPU mapping is a two-stage streaming schedule over VMEM-sized
blocks expressed with ``BlockSpec``:

  stage 1  stream g,e HBM->VMEM, emit p and per-block partial L1 sums
  (host)   scale = sum(partials) / d   -- a tiny (num_blocks,) reduction
  stage 2  stream p HBM->VMEM, emit delta = scale*sign(p) and e' = p - delta

Block size is a multiple of the 8x128 VPU lane layout. On this image the
kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the block structure is still the one a real TPU would use.
DESIGN.md section "Hardware adaptation" discusses the mapping; the analytic
VMEM/bandwidth model is in EXPERIMENTS.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes x 128 lanes x 8 = 8192 elements per block: 32 KiB of f32 per
# operand, comfortably inside a 16 MiB VMEM budget for the 5 resident blocks
# (g, e, p, delta, e').
BLOCK = 8192


def _stage1_kernel(gamma_ref, g_ref, e_ref, p_ref, partial_ref):
    """p = gamma*g + e and the block's partial L1 sum."""
    p = gamma_ref[0] * g_ref[...] + e_ref[...]
    p_ref[...] = p
    partial_ref[0] = jnp.sum(jnp.abs(p))


def _stage2_kernel(scale_ref, p_ref, delta_ref, err_ref):
    """delta = scale * sign(p), e' = p - delta."""
    p = p_ref[...]
    delta = scale_ref[0] * jnp.sign(p)
    delta_ref[...] = delta
    err_ref[...] = p - delta


def _pad_to_block(v):
    d = v.shape[0]
    rem = (-d) % BLOCK
    if rem:
        v = jnp.concatenate([v, jnp.zeros((rem,), v.dtype)])
    return v


@partial(jax.jit, static_argnames=("interpret",))
def ef_sign_step(g, e, gamma, interpret=True):
    """Fused EF scaled-sign step.

    Args:
      g: flat stochastic gradient, shape (d,), float32.
      e: flat residual error, shape (d,), float32.
      gamma: learning rate, shape (1,), float32.

    Returns:
      (delta, e_new): the applied update ``(||p||_1/d) sign(p)`` and the new
      residual, both shape (d,). The exact invariant ``delta + e_new == p``
      holds bit-for-bit (both stages compute from the same stored p).
    """
    d = g.shape[0]
    gp = _pad_to_block(g)
    ep = _pad_to_block(e)
    dp = gp.shape[0]
    nblk = dp // BLOCK

    p, partials = pl.pallas_call(
        _stage1_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # gamma broadcast to blocks
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
        ],
        interpret=interpret,
    )(gamma, gp, ep)

    # Padding contributes |0| = 0, so the padded L1 sum equals the true one.
    # Divide by the true d: the compressor scale is ||p||_1 / d.
    scale = (jnp.sum(partials) / d).reshape(1)

    delta, err = pl.pallas_call(
        _stage2_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
        ],
        interpret=interpret,
    )(scale, p)

    return delta[:d], err[:d]


def _mask_kernel(thr_ref, p_ref, delta_ref, err_ref):
    """Keep coordinates with |p| >= threshold, residual gets the rest."""
    p = p_ref[...]
    keep = jnp.abs(p) >= thr_ref[0]
    delta = jnp.where(keep, p, 0.0)
    delta_ref[...] = delta
    err_ref[...] = p - delta


@partial(jax.jit, static_argnames=("k", "interpret"))
def ef_topk_step(g, e, gamma, *, k, interpret=True):
    """Fused EF top-k step: keep the k largest-magnitude coordinates of
    p = gamma*g + e, residual keeps the rest.

    The k-th magnitude is found with a sort at the JAX level (``lax.top_k``
    emits a ``topk(..., largest=true)`` HLO instruction that xla_extension
    0.5.1's text parser rejects; ``sort`` round-trips cleanly); the
    bandwidth-heavy masking pass is the Pallas kernel. Coordinates tied with the k-th magnitude are all kept, so
    the kept count can exceed k on ties — the Rust reference implements the
    same threshold semantics.

    Returns (delta, e_new) with delta + e_new == p exactly.
    """
    d = g.shape[0]
    p_full = gamma[0] * g + e
    thr = jnp.sort(jnp.abs(p_full))[d - k].reshape(1)

    pp = _pad_to_block(p_full)
    dp = pp.shape[0]
    nblk = dp // BLOCK
    delta, err = pl.pallas_call(
        _mask_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
        ],
        interpret=interpret,
    )(thr, pp)
    return delta[:d], err[:d]


@partial(jax.jit, static_argnames=("interpret",))
def density(v, interpret=True):
    """phi(v) = ||v||_1^2 / (d ||v||_2^2), the paper's gradient density
    (Lemma 8): the scaled-sign operator is a phi(v)-approximate compressor.

    Computed with a single Pallas reduction pass (partial L1 and L2 sums per
    block).
    """

    def kernel(v_ref, l1_ref, l2_ref):
        x = v_ref[...]
        l1_ref[0] = jnp.sum(jnp.abs(x))
        l2_ref[0] = jnp.sum(x * x)

    d = v.shape[0]
    vp = _pad_to_block(v)
    nblk = vp.shape[0] // BLOCK
    l1p, l2p = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
        ],
        interpret=interpret,
    )(vp)
    l1 = jnp.sum(l1p)
    l2 = jnp.sum(l2p)
    return jnp.where(l2 > 0, l1 * l1 / (d * l2), 1.0)
