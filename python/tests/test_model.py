"""L2 model correctness: shapes, gradients, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def theta():
    return jnp.asarray(M.init_params(CFG, seed=0))


@pytest.fixture(scope="module")
def tokens():
    return jnp.asarray(M.make_example_tokens(CFG, seed=1))


def test_param_spec_consistent():
    d = M.num_params(CFG)
    assert d == sum(int(np.prod(s)) for _, s in M.param_spec(CFG))
    theta = M.init_params(CFG, seed=0)
    assert theta.shape == (d,)
    assert theta.dtype == np.float32


def test_unflatten_roundtrip(theta):
    params = M.unflatten(theta, CFG)
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == M.num_params(CFG)
    # layout order: concatenating back reproduces theta
    flat = jnp.concatenate([params[n].reshape(-1) for n, _ in M.param_spec(CFG)])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(theta))


def test_forward_shape(theta, tokens):
    logits = M.forward(theta, tokens[:, :-1], CFG)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_near_uniform_at_init(theta, tokens):
    """With 0.02-scale init the model is near-uniform: loss ~ log(vocab)."""
    loss = float(M.loss_fn(theta, tokens, CFG))
    assert abs(loss - np.log(CFG.vocab)) < 0.5


def test_grad_shape_and_finite(theta, tokens):
    loss, grad = M.lm_step(theta, tokens, CFG)
    assert grad.shape == theta.shape
    assert bool(jnp.all(jnp.isfinite(grad)))
    assert float(jnp.linalg.norm(grad)) > 0


def test_grad_matches_finite_differences(theta, tokens):
    """Spot-check autodiff against central differences on a few coords."""
    _, grad = M.lm_step(theta, tokens, CFG)
    rng = np.random.default_rng(0)
    idxs = rng.integers(0, theta.shape[0], 5)
    eps = 1e-2
    for i in idxs:
        tp = theta.at[i].add(eps)
        tm = theta.at[i].add(-eps)
        fd = (float(M.loss_fn(tp, tokens, CFG)) - float(M.loss_fn(tm, tokens, CFG))) / (
            2 * eps
        )
        assert abs(fd - float(grad[i])) < 5e-3 + 0.2 * abs(fd), (
            f"coord {i}: fd={fd} ad={float(grad[i])}"
        )


def test_causality(theta):
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(2)
    toks = rng.integers(0, CFG.vocab, (1, CFG.seq), dtype=np.int32)
    a = M.forward(jnp.asarray(theta), jnp.asarray(toks), CFG)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % CFG.vocab
    b = M.forward(jnp.asarray(theta), jnp.asarray(toks2), CFG)
    np.testing.assert_allclose(
        np.asarray(a[0, : CFG.seq - 1]), np.asarray(b[0, : CFG.seq - 1]), atol=1e-5
    )


def test_sgd_steps_reduce_loss(theta, tokens):
    """A few full-batch GD steps on one batch must reduce the loss."""
    t = theta
    first = float(M.loss_fn(t, tokens, CFG))
    step = jax.jit(lambda th: M.lm_step(th, tokens, CFG))
    for _ in range(5):
        loss, grad = step(t)
        t = t - 0.5 * grad
    last = float(M.loss_fn(t, tokens, CFG))
    assert last < first - 0.05, f"{first} -> {last}"


def test_lm_step_ef_consistent_with_parts(theta, tokens):
    """The fused artifact == train step followed by the EF kernel."""
    e = jnp.asarray(np.random.default_rng(3).normal(0, 0.01, theta.shape[0]).astype(np.float32))
    ga = jnp.array([0.1], dtype=jnp.float32)
    loss_f, delta_f, enew_f = M.lm_step_ef(theta, e, tokens, ga, CFG)
    loss_p, grad = M.lm_step(theta, tokens, CFG)
    from compile.kernels import ef_sign
    delta_p, enew_p = ef_sign.ef_sign_step(grad, e, ga)
    np.testing.assert_allclose(float(loss_f), float(loss_p), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(delta_f), np.asarray(delta_p), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(enew_f), np.asarray(enew_p), rtol=1e-5, atol=1e-7)


def test_init_is_deterministic():
    a = M.init_params(CFG, seed=0)
    b = M.init_params(CFG, seed=0)
    np.testing.assert_array_equal(a, b)
    c = M.init_params(CFG, seed=1)
    assert not np.array_equal(a, c)
