"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis drives shapes/values; every property here is an invariant the
paper's analysis relies on (Algorithm 1 semantics, Lemma 8 compression
factor, exact residual bookkeeping).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ef_sign, ref

SIZES = st.sampled_from([1, 2, 7, 128, 1000, 8192, 8193, 16384, 20000])


def make_vec(d, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, d).astype(np.float32))


# ---------------------------------------------------------------- ef_sign


@settings(max_examples=25, deadline=None)
@given(d=SIZES, seed=st.integers(0, 2**31 - 1), gamma=st.floats(1e-4, 10.0))
def test_ef_sign_matches_ref(d, seed, gamma):
    g = make_vec(d, seed)
    e = make_vec(d, seed + 1)
    ga = jnp.array([gamma], dtype=jnp.float32)
    delta, err = ef_sign.ef_sign_step(g, e, ga)
    dref, eref = ref.ef_sign_step_ref(g, e, ga)
    # f32 L1-sum accumulation order differs (tiled vs flat).
    tol = 1e-4 * max(1.0, gamma)
    np.testing.assert_allclose(delta, dref, rtol=1e-3, atol=tol)
    np.testing.assert_allclose(err, eref, rtol=1e-3, atol=tol)


@settings(max_examples=15, deadline=None)
@given(d=SIZES, seed=st.integers(0, 2**31 - 1))
def test_ef_sign_residual_identity(d, seed):
    """delta + e' == p bit-for-bit: nothing is lost by the compressor+EF pair."""
    g = make_vec(d, seed)
    e = make_vec(d, seed + 7)
    ga = jnp.array([0.3], dtype=jnp.float32)
    delta, err = ef_sign.ef_sign_step(g, e, ga)
    p = ga[0] * g + e
    np.testing.assert_allclose(np.asarray(delta) + np.asarray(err), p, rtol=0, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(d=st.sampled_from([128, 1000, 8192, 20000]), seed=st.integers(0, 2**31 - 1))
def test_ef_sign_is_delta_compressor(d, seed):
    """Lemma 8: ||C(p) - p||^2 <= (1 - phi(p)) ||p||^2."""
    g = make_vec(d, seed)
    e = jnp.zeros_like(g)
    ga = jnp.array([1.0], dtype=jnp.float32)
    delta, err = ef_sign.ef_sign_step(g, e, ga)
    p = np.asarray(g)
    phi = float(ref.density_ref(g))
    lhs = float(np.sum(np.asarray(err) ** 2))
    rhs = (1.0 - phi) * float(np.sum(p**2))
    assert lhs <= rhs * (1.0 + 1e-4) + 1e-6


def test_ef_sign_zero_vector():
    d = 512
    z = jnp.zeros((d,), jnp.float32)
    ga = jnp.array([1.0], dtype=jnp.float32)
    delta, err = ef_sign.ef_sign_step(z, z, ga)
    assert float(jnp.max(jnp.abs(delta))) == 0.0
    assert float(jnp.max(jnp.abs(err))) == 0.0


def test_ef_sign_constant_vector_lossless():
    """For a constant-magnitude vector, phi = 1 and compression is exact."""
    d = 4096
    p = jnp.ones((d,), jnp.float32) * 0.7
    ga = jnp.array([1.0], dtype=jnp.float32)
    delta, err = ef_sign.ef_sign_step(p, jnp.zeros_like(p), ga)
    np.testing.assert_allclose(delta, p, rtol=1e-6)
    np.testing.assert_allclose(err, jnp.zeros_like(p), atol=1e-6)


def test_ef_sign_scale_is_l1_over_d():
    d = 1000
    g = make_vec(d, 3)
    ga = jnp.array([1.0], dtype=jnp.float32)
    delta, _ = ef_sign.ef_sign_step(g, jnp.zeros_like(g), ga)
    expected = float(jnp.sum(jnp.abs(g))) / d
    mags = np.unique(np.abs(np.asarray(delta)))
    mags = mags[mags > 0]
    assert mags.size >= 1
    np.testing.assert_allclose(mags, expected, rtol=1e-5)


@pytest.mark.parametrize("gamma", [1e-6, 1e-2, 1.0, 100.0])
def test_ef_sign_gamma_sweep(gamma):
    d = 8192 + 5  # non-multiple of BLOCK exercises padding
    g = make_vec(d, 11)
    e = make_vec(d, 12)
    ga = jnp.array([gamma], dtype=jnp.float32)
    delta, err = ef_sign.ef_sign_step(g, e, ga)
    dref, eref = ref.ef_sign_step_ref(g, e, ga)
    # f32 accumulation order differs between the tiled kernel and the flat
    # reference; tolerance scales with the magnitude of p ~ gamma.
    tol = 1e-4 * max(1.0, gamma)
    np.testing.assert_allclose(delta, dref, rtol=2e-3, atol=tol)
    np.testing.assert_allclose(err, eref, rtol=2e-3, atol=tol)


# ---------------------------------------------------------------- top-k


@settings(max_examples=20, deadline=None)
@given(
    d=st.sampled_from([16, 128, 1000, 8192, 9000]),
    seed=st.integers(0, 2**31 - 1),
    kfrac=st.sampled_from([1, 4, 16, 64]),
)
def test_topk_matches_ref(d, seed, kfrac):
    k = max(1, d // kfrac)
    g = make_vec(d, seed)
    e = make_vec(d, seed + 5)
    ga = jnp.array([0.5], dtype=jnp.float32)
    delta, err = ef_sign.ef_topk_step(g, e, ga, k=k)
    dref, eref = ref.ef_topk_step_ref(g, e, ga, k)
    np.testing.assert_allclose(delta, dref, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(err, eref, rtol=1e-6, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([64, 1000, 8192]), seed=st.integers(0, 2**31 - 1))
def test_topk_keeps_at_least_k(d, seed):
    k = max(1, d // 8)
    g = make_vec(d, seed)
    ga = jnp.array([1.0], dtype=jnp.float32)
    delta, _ = ef_sign.ef_topk_step(g, jnp.zeros_like(g), ga, k=k)
    nz = int(jnp.sum(delta != 0))
    assert nz >= k  # ties can push it above k; gaussian values make == k a.s.
    assert nz <= d


def test_topk_k_equals_d_is_identity():
    d = 700
    g = make_vec(d, 21)
    ga = jnp.array([1.0], dtype=jnp.float32)
    delta, err = ef_sign.ef_topk_step(g, jnp.zeros_like(g), ga, k=d)
    np.testing.assert_allclose(delta, g, rtol=1e-6)
    np.testing.assert_allclose(err, jnp.zeros_like(g), atol=1e-7)


def test_topk_contraction_bound():
    """top-k is a (k/d)-approximate compressor (Stich et al. Lemma A.1)."""
    d, k = 2048, 32
    g = make_vec(d, 33)
    ga = jnp.array([1.0], dtype=jnp.float32)
    _, err = ef_sign.ef_topk_step(g, jnp.zeros_like(g), ga, k=k)
    lhs = float(jnp.sum(err**2))
    rhs = (1.0 - k / d) * float(jnp.sum(g**2))
    assert lhs <= rhs * (1 + 1e-5)


# ---------------------------------------------------------------- density


@settings(max_examples=20, deadline=None)
@given(d=SIZES, seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_density_matches_ref(d, seed, scale):
    v = make_vec(d, seed, scale)
    phi = float(ef_sign.density(v))
    phir = float(ref.density_ref(v))
    np.testing.assert_allclose(phi, phir, rtol=1e-4, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([2, 64, 1000, 8192]), seed=st.integers(0, 2**31 - 1))
def test_density_in_unit_interval(d, seed):
    v = make_vec(d, seed)
    phi = float(ef_sign.density(v))
    assert 0.0 < phi <= 1.0 + 1e-6


def test_density_extremes():
    d = 1024
    one_hot = jnp.zeros((d,), jnp.float32).at[3].set(5.0)
    np.testing.assert_allclose(float(ef_sign.density(one_hot)), 1.0 / d, rtol=1e-5)
    const = jnp.full((d,), -2.5, jnp.float32)
    np.testing.assert_allclose(float(ef_sign.density(const)), 1.0, rtol=1e-6)
    zero = jnp.zeros((d,), jnp.float32)
    assert float(ef_sign.density(zero)) == 1.0


# ------------------------------------------------- multi-step EF dynamics


def test_ef_iteration_tracks_sgd_sum():
    """The proof-sketch identity x_t - e_t == x_0 - sum_i gamma*g_i:
    the error-corrected EF iterate equals the SGD trajectory exactly."""
    d = 4096
    rng = np.random.default_rng(123)
    x = jnp.zeros((d,), jnp.float32)
    e = jnp.zeros((d,), jnp.float32)
    ga = jnp.array([0.05], dtype=jnp.float32)
    acc = np.zeros(d, dtype=np.float64)
    for t in range(20):
        g = jnp.asarray(rng.normal(0, 1, d).astype(np.float32))
        acc += 0.05 * np.asarray(g, dtype=np.float64)
        delta, e = ef_sign.ef_sign_step(g, e, ga)
        x = x - delta
    np.testing.assert_allclose(
        np.asarray(x, dtype=np.float64) - np.asarray(e, dtype=np.float64),
        -acc,
        rtol=1e-3,
        atol=1e-4,
    )


def test_ef_error_norm_stays_bounded():
    """Lemma 3 qualitatively: ||e_t|| does not blow up over many steps."""
    d = 8192
    rng = np.random.default_rng(7)
    e = jnp.zeros((d,), jnp.float32)
    ga = jnp.array([0.1], dtype=jnp.float32)
    norms = []
    for t in range(60):
        g = jnp.asarray(rng.normal(0, 1, d).astype(np.float32))
        _, e = ef_sign.ef_sign_step(g, e, ga)
        norms.append(float(jnp.linalg.norm(e)))
    assert max(norms[30:]) < 10.0 * np.median(norms[30:])
