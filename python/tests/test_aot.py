"""AOT path: HLO text artifacts are emitted, well-formed and self-consistent.

Full numeric validation of the artifacts happens on the Rust side
(rust/tests/runtime_integration.rs executes them via PJRT and compares with
rust-native references); here we validate the python half of the contract.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_files(manifest):
    for cfg in manifest["configs"]:
        for art in cfg["artifacts"]:
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), art["file"]
            assert os.path.getsize(path) == art["bytes"]
        assert os.path.exists(os.path.join(ART, cfg["init_params"]))


def test_hlo_text_is_parseable_hlo(manifest):
    for cfg in manifest["configs"]:
        for art in cfg["artifacts"]:
            with open(os.path.join(ART, art["file"])) as f:
                head = f.read(4096)
            assert "HloModule" in head, art["file"]
            assert "ENTRY" in head or "ENTRY" in open(os.path.join(ART, art["file"])).read()


def test_manifest_d_matches_model(manifest):
    for cfg in manifest["configs"]:
        mc = M.CONFIGS[cfg["name"]]
        assert cfg["d"] == M.num_params(mc)
        assert cfg["vocab"] == mc.vocab
        assert cfg["seq"] == mc.seq
        assert cfg["batch"] == mc.batch


def test_init_params_bin_shape_and_values(manifest):
    for cfg in manifest["configs"]:
        raw = np.fromfile(os.path.join(ART, cfg["init_params"]), dtype=np.float32)
        assert raw.shape[0] == cfg["d"]
        expected = M.init_params(M.CONFIGS[cfg["name"]], seed=0)
        np.testing.assert_array_equal(raw, expected)


def test_lowering_is_deterministic():
    """Same function+shapes must produce identical HLO text (caching and
    sha256 bookkeeping in the manifest rely on this)."""
    cfg = M.CONFIGS["tiny"]
    d = M.num_params(cfg)
    vec = jax.ShapeDtypeStruct((d,), jnp.float32)
    ga = jax.ShapeDtypeStruct((1,), jnp.float32)
    a = aot.lower(M.ef_sign_artifact, vec, vec, ga)
    b = aot.lower(M.ef_sign_artifact, vec, vec, ga)
    assert a == b


def test_roundtrip_execute_matches_jax(manifest):
    """Re-lower the function and compare against the emitted HLO text, then
    check the jitted numerics against the oracle — guards lowering drift."""
    from jax._src.lib import xla_client as xc

    cfg = M.CONFIGS["tiny"]
    d = M.num_params(cfg)
    rng = np.random.default_rng(0)
    g = rng.normal(0, 1, d).astype(np.float32)
    e = rng.normal(0, 1, d).astype(np.float32)
    ga = np.array([0.1], dtype=np.float32)

    with open(os.path.join(ART, "ef_sign_tiny.hlo.txt")) as f:
        text = f.read()
    backend = jax.devices("cpu")[0].client
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(jax.jit(M.ef_sign_artifact).lower(
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ).compiler_ir("stablehlo")),
        use_tuple_args=False,
        return_tuple=True,
    )
    assert comp.as_hlo_text() == text

    delta, enew = M.ef_sign_artifact(jnp.asarray(g), jnp.asarray(e), jnp.asarray(ga))
    from compile.kernels import ref

    dref, eref = ref.ef_sign_step_ref(jnp.asarray(g), jnp.asarray(e), jnp.asarray(ga))
    np.testing.assert_allclose(np.asarray(delta), np.asarray(dref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(enew), np.asarray(eref), rtol=1e-5, atol=1e-6)
