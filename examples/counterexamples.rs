//! Runs the paper's §3 counterexamples end to end and prints the
//! trajectories: where SIGNSGD provably fails and error feedback fixes it.
//!
//! Run: `cargo run --release --example counterexamples [--quick]`

use ef_sgd::experiments::{self, ExpContext};

fn main() -> anyhow::Result<()> {
    ef_sgd::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let ctx = ExpContext {
        quick,
        ..Default::default()
    };
    for id in ["ce1", "ce2", "ce3", "thm1"] {
        experiments::run(id, &ctx)?;
        println!();
    }
    Ok(())
}
