//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled JAX transformer (L2) whose EF compression step is
//! the Pallas kernel (L1), and trains it with the Rust distributed
//! coordinator (L3): 4 workers on a Markov-corpus LM task, EF-SIGNSGD
//! exchange over the simulated fabric with exact bit accounting, loss
//! logged every round. Proves all layers compose; the recorded run lives in
//! EXPERIMENTS.md §E2E.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_transformer [--quick] [--model small]
//!     [--steps N] [--workers N] [--threads N] [--fused]
//!
//! `--fused` uses the single-dispatch lm_step_ef artifact (train step + EF
//! compression in one PJRT execute) — the optimized single-worker path.

use anyhow::{anyhow, Context, Result};
use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver, UpdateRule};
use ef_sgd::coordinator::worker::{GradSource, Worker, WorkerMode};
use ef_sgd::coordinator::{Aggregation, LrSchedule};
use ef_sgd::data::tokens::MarkovCorpus;
use ef_sgd::metrics::sparkline;
use ef_sgd::net::MessageKind;
use ef_sgd::runtime::{LmSession, Runtime};
use ef_sgd::util::timer::Timer;
use ef_sgd::util::Pcg64;
use std::sync::Arc;

struct LmWorkerSource {
    session: Arc<LmSession>,
    corpus: Arc<MarkovCorpus>,
    rng: Pcg64,
    eval_rng: Pcg64,
}

impl GradSource for LmWorkerSource {
    fn dim(&self) -> usize {
        self.session.d()
    }

    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64 {
        let (b, s) = self.session.model.token_shape();
        let tokens = self.corpus.sample_batch(b, s, &mut self.rng);
        let (loss, grad) = self.session.train_step(theta, &tokens).expect("lm step");
        out.copy_from_slice(&grad);
        loss
    }

    fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        let (b, s) = self.session.model.token_shape();
        let tokens = self.corpus.sample_batch(b, s, &mut self.eval_rng);
        self.session.eval(theta, &tokens).unwrap_or(f64::NAN)
    }
}

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> Result<()> {
    ef_sgd::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let fused = std::env::args().any(|a| a == "--fused");
    let model = arg("--model").unwrap_or_else(|| if quick { "tiny" } else { "small" }.into());
    let steps: usize = arg("--steps")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 30 } else { 300 });
    let workers: usize = arg("--workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fused { 1 } else { 4 });
    let threads: usize = arg("--threads").and_then(|s| s.parse().ok()).unwrap_or(1);
    let lr: f64 = arg("--lr").and_then(|s| s.parse().ok()).unwrap_or(1.0);

    let rt = Runtime::load_default()
        .context("artifacts missing — run `make artifacts` first")?;
    let session = Arc::new(LmSession::open(&rt, &model)?);
    let d = session.d();
    let entry = &session.model;
    let corpus = Arc::new(MarkovCorpus::new(entry.vocab, 4, 0));
    let mut ent_rng = Pcg64::seeded(99);
    let entropy = corpus.entropy_estimate(20_000, &mut ent_rng);
    println!(
        "e2e: model={model} d={d} vocab={} seq={} batch={} | workers={workers} steps={steps}",
        entry.vocab, entry.seq, entry.batch
    );
    println!(
        "corpus entropy ~{entropy:.3} nats/token (uniform = {:.3}) — the loss floor\n",
        (entry.vocab as f64).ln()
    );
    let theta0 = rt.init_params(entry).map_err(|e| anyhow!("{e}"))?;

    if fused {
        run_fused(&session, &corpus, theta0, steps, lr as f32, entropy)
    } else {
        run_distributed(session, corpus, theta0, steps, workers, threads, lr, entropy)
    }
}

/// Multi-worker path: the coordinator drives lm_step per worker, EF-sign
/// compression + parameter-server exchange on the fabric.
#[allow(clippy::too_many_arguments)]
fn run_distributed(
    session: Arc<LmSession>,
    corpus: Arc<MarkovCorpus>,
    theta0: Vec<f32>,
    steps: usize,
    n_workers: usize,
    threads: usize,
    lr: f64,
    entropy: f64,
) -> Result<()> {
    let workers: Vec<Worker> = (0..n_workers)
        .map(|id| {
            Worker::new(
                id,
                Box::new(LmWorkerSource {
                    session: session.clone(),
                    corpus: corpus.clone(),
                    rng: Pcg64::new(0, 1000 + id as u64),
                    eval_rng: Pcg64::new(0, 5000 + id as u64),
                }),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                64,
                4,
                Pcg64::new(0, id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::new(lr, steps, vec![0.5, 0.75]),
        aggregation: Aggregation::Mean,
        update_rule: UpdateRule::ApplyAggregate,
        threads,
        log_every: 10,
        eval_every: (steps / 10).max(1),
        ..Default::default()
    };
    let t = Timer::start();
    let out = TrainDriver::new(cfg, workers, theta0).run();
    let wall = t.elapsed_secs();

    let losses = &out.recorder.get("train_loss").unwrap().values;
    let phi = &out.recorder.get("phi_corrected").unwrap().values;
    println!("\n== e2e transformer (distributed EF-SIGNSGD) ==");
    println!(
        "  loss: {:.4} -> {:.4} (floor ~{entropy:.3})   {}",
        losses.first().unwrap(),
        losses.last().unwrap(),
        sparkline(losses, 50)
    );
    println!(
        "  phi(g+e) (Fig 2 series): min {:.3} mean {:.3}",
        phi.iter().cloned().fold(f64::INFINITY, f64::min),
        crate_mean(phi)
    );
    println!(
        "  eval loss: {:.4}",
        out.recorder.last("eval_loss")
    );
    let push = out.traffic.bits_of_kind(MessageKind::GradPush);
    let dense = 32u64 * out.theta.len() as u64 * out.rounds * n_workers as u64;
    println!(
        "  comm: push {:.2} Mbit vs dense-equivalent {:.2} Mbit  => {:.1}x saved",
        push as f64 / 1e6,
        dense as f64 / 1e6,
        dense as f64 / push as f64
    );
    println!(
        "  wallclock {:.1}s  ({:.1} rounds/s, {} workers x {} steps)",
        wall,
        out.rounds as f64 / wall,
        n_workers,
        out.rounds
    );
    Ok(())
}

/// Single-worker fused path: one PJRT dispatch per step via lm_step_ef
/// (the Pallas EF-sign kernel fused into the training step's HLO).
fn run_fused(
    session: &LmSession,
    corpus: &MarkovCorpus,
    theta0: Vec<f32>,
    steps: usize,
    lr: f32,
    entropy: f64,
) -> Result<()> {
    let d = session.d();
    let (b, s) = session.model.token_shape();
    let mut theta = theta0;
    let mut e = vec![0.0f32; d];
    let mut rng = Pcg64::seeded(1);
    let mut losses = Vec::new();
    let t = Timer::start();
    for step in 0..steps {
        let gamma = if step >= steps / 2 { lr * 0.1 } else { lr };
        let tokens = corpus.sample_batch(b, s, &mut rng);
        let (loss, delta, e_new) = session.train_step_ef(&theta, &e, &tokens, gamma)?;
        ef_sgd::tensor::sub_assign(&mut theta, &delta);
        e = e_new;
        losses.push(loss);
        if step % 10 == 0 {
            log::info!("fused step {step}: loss {loss:.4}");
        }
    }
    let wall = t.elapsed_secs();
    println!("\n== e2e transformer (fused single-dispatch EF-SIGNSGD) ==");
    println!(
        "  loss: {:.4} -> {:.4} (floor ~{entropy:.3})   {}",
        losses.first().unwrap(),
        losses.last().unwrap(),
        sparkline(&losses, 50)
    );
    println!(
        "  residual ||e|| = {:.4}",
        ef_sgd::tensor::norm2(&e)
    );
    println!("  wallclock {wall:.1}s  ({:.1} steps/s)", steps as f64 / wall);
    Ok(())
}

fn crate_mean(v: &[f64]) -> f64 {
    ef_sgd::util::stats::mean(v)
}
