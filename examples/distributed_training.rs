//! Distributed training over the simulated fabric: 8 workers train the MLP
//! classifier under three gradient-exchange regimes and we compare loss,
//! accuracy, and measured communication (the paper's core tradeoff).
//!
//! Run: `cargo run --release --example distributed_training [--quick]`

use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::async_driver::AsyncTrainDriver;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver, UpdateRule};
use ef_sgd::coordinator::worker::{GradSource, ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::net::{StragglerModel, StragglerSchedule};
use ef_sgd::data::synth_class::{self, Dataset, SynthSpec};
use ef_sgd::data::Sharder;
use ef_sgd::metrics::sparkline;
use ef_sgd::model::mlp::{Mlp, MlpObjective};
use ef_sgd::net::MessageKind;
use ef_sgd::util::Pcg64;

/// GradSource wrapper that also evaluates test accuracy.
struct ShardSource {
    inner: ObjectiveSource<MlpObjective>,
    test: Dataset,
}

impl GradSource for ShardSource {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64 {
        self.inner.grad(theta, out)
    }

    fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        self.inner.obj.mlp.dataset_loss(theta, &self.test)
    }

    fn eval_acc(&mut self, theta: &[f32]) -> f64 {
        self.inner.obj.mlp.accuracy(theta, &self.test)
    }
}

fn main() {
    ef_sgd::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 100 } else { 1_500 };
    let n_workers = 8;

    let spec = SynthSpec::cifar100_like();
    let mut rng = Pcg64::seeded(7);
    let (train, test) = synth_class::generate(&spec, &mut rng);
    let mlp = Mlp::new(ef_sgd::experiments::lr_tuning::mlp_config(&spec));
    let d = mlp.cfg.num_params();
    println!(
        "distributed run: {n_workers} workers, d={d}, {} train examples, {steps} rounds\n",
        train.len()
    );

    let regimes: [(&str, WorkerMode, CompressorKind, UpdateRule, f64); 3] = [
        (
            "dense SGDM",
            WorkerMode::DenseGrad,
            CompressorKind::None,
            UpdateRule::ServerMomentum { beta_millis: 900 },
            0.02,
        ),
        (
            "EF-SIGNSGD",
            WorkerMode::ErrorFeedback,
            CompressorKind::ScaledSign,
            UpdateRule::ApplyAggregate,
            0.02,
        ),
        (
            "EF top-k (1/64)",
            WorkerMode::ErrorFeedback,
            CompressorKind::TopK,
            UpdateRule::ApplyAggregate,
            0.05,
        ),
    ];

    for (name, mode, kind, rule, lr) in regimes {
        let mut shard_rng = Pcg64::seeded(11);
        let sharder = Sharder::new(&train, n_workers, &mut shard_rng);
        let workers: Vec<Worker> = sharder
            .shards
            .iter()
            .enumerate()
            .map(|(id, shard)| {
                Worker::new(
                    id,
                    Box::new(ShardSource {
                        inner: ObjectiveSource::new(
                            MlpObjective::new(mlp.clone(), shard.clone(), 16),
                            Pcg64::new(3, id as u64),
                        ),
                        test: test.clone(),
                    }),
                    mode,
                    kind,
                    64,
                    4,
                    Pcg64::new(4, id as u64),
                )
            })
            .collect();
        let theta0 = mlp.init_params(&mut Pcg64::seeded(5));
        let cfg = DriverConfig {
            steps,
            schedule: LrSchedule::new(lr, steps, vec![0.5, 0.75]),
            update_rule: rule,
            eval_every: (steps / 10).max(1),
            ..Default::default()
        };
        let out = TrainDriver::new(cfg, workers, theta0).run();
        let losses = &out.recorder.get("train_loss").unwrap().values;
        let acc = out.recorder.last("eval_acc");
        let push = out.traffic.bits_of_kind(MessageKind::GradPush);
        println!(
            "{name:<16} loss {:.3} -> {:.3}  test acc {:5.1}%  push {:>11.2} Mbit  {}",
            losses.first().unwrap(),
            losses.last().unwrap(),
            100.0 * acc,
            push as f64 / 1e6,
            sparkline(losses, 36)
        );
        println!(
            "{:16} critical-path comm {:.2} ms (simulated 10GbE)",
            "",
            out.traffic.critical_path_s() * 1e3
        );
    }
    println!("\nshape to observe: EF variants track dense accuracy at a fraction of the bits.");

    // ---- async mode: bounded-staleness rounds under stragglers --------
    // The same EF-SIGNSGD workload, but the leader folds as soon as half
    // the workers' frames arrive (quorum 4/8) and tolerates frames up to
    // 2 rounds late, while per-worker compute time follows a heavy-tail
    // lognormal (sigma = 1). Equivalent CLI:
    //   repro train --async --quorum 4 --max-staleness 2 \
    //               --straggler lognormal:1.0 --compute-ms 1
    println!("\n== async: quorum 4/8, max staleness 2, lognormal stragglers ==");
    let mut shard_rng = Pcg64::seeded(11);
    let sharder = Sharder::new(&train, n_workers, &mut shard_rng);
    let workers: Vec<Worker> = sharder
        .shards
        .iter()
        .enumerate()
        .map(|(id, shard)| {
            Worker::new(
                id,
                Box::new(ShardSource {
                    inner: ObjectiveSource::new(
                        MlpObjective::new(mlp.clone(), shard.clone(), 16),
                        Pcg64::new(3, id as u64),
                    ),
                    test: test.clone(),
                }),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                64,
                4,
                Pcg64::new(4, id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::new(0.02, steps, vec![0.5, 0.75]),
        straggler: StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma: 1.0 }, 7),
        eval_every: (steps / 10).max(1),
        ..Default::default()
    };
    let theta0 = mlp.init_params(&mut Pcg64::seeded(5));
    let out = AsyncTrainDriver::new(cfg, n_workers / 2, 2, workers, theta0).run();
    let losses = &out.recorder.get("train_loss").unwrap().values;
    println!(
        "async EF-SIGNSGD  loss {:.3} -> {:.3}  test acc {:5.1}%  {}",
        losses.first().unwrap(),
        losses.last().unwrap(),
        100.0 * out.recorder.last("eval_acc"),
        sparkline(losses, 36)
    );
    println!(
        "  {} folds: mean batch {:.1}/{n_workers}, {:.1}% stale frames (max staleness {}), sim time {:.2} s",
        out.rounds,
        out.staleness.mean_batch(),
        100.0 * out.staleness.stale_fraction(),
        out.staleness.max_staleness_seen,
        out.sim_time_s
    );
    println!("shape: the quorum hides stragglers; EF's residual absorbs the late frames.");
}
