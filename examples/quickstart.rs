//! Quickstart: the EF-SGD public API in ~60 lines.
//!
//! Trains a small classifier three ways — SGDM, scaled SIGNSGD (no
//! feedback), and EF-SIGNSGD — and prints the accuracies plus the exact
//! number of bits each method would put on the wire per step.
//!
//! Run: `cargo run --release --example quickstart`

use ef_sgd::compress::{Compressor, ScaledSign};
use ef_sgd::data::synth_class::{self, SynthSpec};
use ef_sgd::model::mlp::{Mlp, MlpConfig, MlpObjective};
use ef_sgd::model::StochasticObjective;
use ef_sgd::optim;
use ef_sgd::util::Pcg64;

fn main() {
    // 1. a synthetic classification task (train/test split)
    let spec = SynthSpec::cifar10_like();
    let mut rng = Pcg64::seeded(0);
    let (train, test) = synth_class::generate(&spec, &mut rng);

    // 2. a model over a flat parameter vector
    let mlp = Mlp::new(MlpConfig {
        in_dim: spec.dim,
        hidden: vec![64],
        classes: spec.classes,
    });
    let d = mlp.cfg.num_params();
    println!("model: {d} parameters, {} classes", spec.classes);

    // 3. train with three optimizers from the paper
    for (algo, lr) in [("sgdm", 0.02), ("signsgd", 0.02), ("ef_signsgd", 0.02)] {
        let mut theta = mlp.init_params(&mut Pcg64::seeded(1));
        let obj = MlpObjective::new(mlp.clone(), train.clone(), 64);
        let mut opt = optim::build(algo, d, lr, 0.9, 0).unwrap();
        let mut g = vec![0.0f32; d];
        let mut data_rng = Pcg64::seeded(2);
        let steps = 1500;
        for t in 0..steps {
            if t == steps / 2 {
                let lr = opt.lr();
                opt.set_lr(lr * 0.1);
            }
            obj.stoch_grad(&theta, &mut data_rng, &mut g);
            opt.step(&mut theta, &g);
        }
        println!(
            "{algo:<12} train acc {:5.1}%   test acc {:5.1}%   residual ||e|| = {:.3}",
            100.0 * mlp.accuracy(&theta, &train),
            100.0 * mlp.accuracy(&theta, &test),
            opt.error_norm(),
        );
    }

    // 4. what goes on the wire: exact bits per gradient push
    let dense_bits = 32 * d as u64;
    let sign_bits = ScaledSign.wire_bits(d);
    println!(
        "\nwire: dense {dense_bits} bits vs scaled-sign {sign_bits} bits  ({:.1}x compression)",
        dense_bits as f64 / sign_bits as f64
    );
}
